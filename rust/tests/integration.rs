//! Integration tests over the full L3 stack on the native CPU backend.
//!
//! Every test runs against a *synthetic in-memory bundle* — no artifacts,
//! no Python, no network, nothing skipped. Exercises: bundle ABI
//! verification, training-step execution + determinism + actual learning,
//! checkpoint resume, held-out evaluation under all routing modes, the
//! layer-sliced decode runtime (skip semantics, capacity drops, cache
//! accounting), and the batching server. The same call sites drive the
//! PJRT backend when built with `--features pjrt` and real artifacts.

use std::sync::Arc;

use mod_transformer::config::{
    FfMode, ModelConfig, RoutingMode, ServeConfig, TrainConfig,
};
use mod_transformer::coordinator::{checkpoint, Trainer, TrainerOptions};
use mod_transformer::data::{BatchIter, CorpusSpec, MarkovCorpus, BOS, EOS, PAD};
use mod_transformer::runtime::{Bundle, SyntheticSpec};
use mod_transformer::serve::{
    argmax, generate_batch, DecodeSession, Engine, Event, GenerateParams,
    Priority, RoutingDecision, ServeErrorKind,
};
use mod_transformer::util::pool;

const SEQ: usize = 32;
const MAX_DECODE: usize = 64;

fn test_model() -> ModelConfig {
    ModelConfig {
        vocab_size: 259,
        d_model: 32,
        n_layers: 4,
        n_heads: 2,
        d_head: 16,
        d_ff: 64,
        seq_len: SEQ,
        routing: RoutingMode::ModInterleaved,
        capacity_frac: 0.125,
        train_predictor: true,
        predictor_hidden: 16,
        ..Default::default()
    }
}

fn test_train() -> TrainConfig {
    TrainConfig {
        batch_size: 4,
        warmup_steps: 5,
        total_steps: 200,
        ..Default::default()
    }
}

/// A synthetic native bundle — the native-backend analogue of opening
/// `artifacts/mod_tiny`, scaled down so the whole suite stays fast.
fn open(name: &str) -> Arc<Bundle> {
    Arc::new(
        Bundle::native(
            name,
            &test_model(),
            &test_train(),
            &SyntheticSpec {
                seed: 7,
                decode_batches: vec![1, 4],
                max_decode_len: MAX_DECODE,
                ..Default::default()
            },
        )
        .expect("synthetic bundle"),
    )
}

fn data_for(bundle: &Arc<Bundle>, seed: u64) -> BatchIter {
    BatchIter::new(
        MarkovCorpus::new(CorpusSpec::default(), seed),
        bundle.manifest.train.batch_size,
        bundle.manifest.model.seq_len,
    )
}

#[test]
fn bundle_abi_is_consistent() {
    let bundle = open("mod_tiny");
    let m = &bundle.manifest;
    // rust-side param accounting matches the manifest
    assert_eq!(m.model.n_params(), m.n_params);
    // every routed layer has a compacted cache, full layers a full cache
    for l in 0..m.model.n_layers {
        let cl = m.cache_len(l).unwrap();
        if m.model.is_routed_block(l) {
            assert!(cl < m.max_decode_len, "layer {l} cache {cl}");
        } else {
            assert_eq!(cl, m.max_decode_len);
        }
    }
    // init params match the ABI exactly
    let params = bundle.init_params().expect("init params");
    assert_eq!(params.len(), m.params.len());
    for (t, spec) in params.iter().zip(&m.params) {
        assert_eq!(t.shape(), spec.shape.as_slice(), "{}", spec.name);
    }
}

#[test]
fn train_step_runs_and_is_deterministic() {
    let bundle = open("mod_tiny");
    let run = |steps: u64| -> Vec<f32> {
        let mut trainer =
            Trainer::new(bundle.clone(), data_for(&bundle, 7), None).unwrap();
        let mut last = Vec::new();
        for s in 0..steps {
            let batch = data_for(&bundle, 7).batch_at(s);
            last = trainer.train_one(&batch).unwrap();
        }
        last
    };
    let a = run(2);
    let b = run(2);
    assert_eq!(a.len(), bundle.manifest.metrics.len());
    assert!(a.iter().all(|v| v.is_finite()), "{a:?}");
    assert_eq!(a, b, "same seed + same steps must reproduce exactly");
}

#[test]
fn training_reduces_loss() {
    let bundle = open("mod_tiny");
    let mut trainer =
        Trainer::new(bundle.clone(), data_for(&bundle, 7), None).unwrap();
    let mut first_ce = f32::NAN;
    let mut last_ce = f32::NAN;
    for s in 0..15 {
        let batch = data_for(&bundle, 7).batch_at(s);
        let m = trainer.train_one(&batch).unwrap();
        if s == 0 {
            first_ce = m[1];
        }
        last_ce = m[1];
    }
    assert!(
        last_ce < first_ce,
        "ce did not improve: {first_ce} -> {last_ce}"
    );
}

#[test]
fn checkpoint_resume_is_bit_exact() {
    let bundle = open("mod_tiny");
    let dir = std::env::temp_dir().join("mod_resume_test");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();

    // run 4 steps straight through
    let mut t1 =
        Trainer::new(bundle.clone(), data_for(&bundle, 7), None).unwrap();
    let mut straight = Vec::new();
    for s in 0..4 {
        straight = t1.train_one(&data_for(&bundle, 7).batch_at(s)).unwrap();
    }

    // run 2 steps, checkpoint, resume, run 2 more
    let mut t2 =
        Trainer::new(bundle.clone(), data_for(&bundle, 7), None).unwrap();
    for s in 0..2 {
        t2.train_one(&data_for(&bundle, 7).batch_at(s)).unwrap();
    }
    let ckpt = dir.join("mid.ckpt");
    t2.save_checkpoint(&ckpt).unwrap();
    let mut t3 =
        Trainer::new(bundle.clone(), data_for(&bundle, 7), Some(&ckpt))
            .unwrap();
    assert_eq!(t3.step(), 2);
    let mut resumed = Vec::new();
    for s in 2..4 {
        resumed = t3.train_one(&data_for(&bundle, 7).batch_at(s)).unwrap();
    }
    assert_eq!(straight, resumed, "resume must be bit-exact");
}

#[test]
fn eval_modes_all_run() {
    let bundle = open("mod_tiny");
    let trainer =
        Trainer::new(bundle.clone(), data_for(&bundle, 7), None).unwrap();
    for mode in ["topk", "router", "predictor"] {
        let e = trainer.evaluate(mode, 1).expect(mode);
        assert!(e.ce.is_finite() && e.ce > 0.0, "{mode}: {e:?}");
        assert!((0.0..=1.0).contains(&e.participation), "{mode}: {e:?}");
    }
    // top-k participation is exactly the capacity fraction
    let e = trainer.evaluate("topk", 1).unwrap();
    let expect = bundle.manifest.model.capacity(bundle.manifest.model.seq_len)
        as f64
        / bundle.manifest.model.seq_len as f64;
    assert!((e.participation - expect).abs() < 1e-5, "{e:?}");
}

#[test]
fn decode_skips_blocks_and_tracks_caches() {
    let bundle = open("mod_tiny");
    let params = bundle.init_params().unwrap();
    let mut session = DecodeSession::new(
        &bundle, &params, 1, RoutingDecision::RouterThreshold,
    )
    .unwrap();
    let mut tok = BOS as i32;
    for _ in 0..32 {
        let logits = session.step(&[tok], &[true]).unwrap();
        assert!(logits.iter().all(|v| v.is_finite()));
        tok = logits
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1))
            .unwrap()
            .0 as i32;
    }
    let rep = session.report();
    assert_eq!(rep.steps, 32);
    // every block is either invoked or skipped, per step
    assert_eq!(
        rep.blocks_invoked + rep.blocks_skipped,
        (bundle.manifest.model.n_layers * 32) as u64
    );
    // full blocks always invoked; routed blocks must skip sometimes —
    // MoD's decode saving is a real non-invocation (acceptance: >0)
    assert!(rep.blocks_invoked >= 2 * 32, "{rep:?}");
    assert!(rep.blocks_skipped > 0, "router never skipped: {rep:?}");
    // cache occupancy: full layers hold exactly one slot per step
    for cs in &rep.cache_stats {
        if !cs.routed {
            let expect = 32.0 / cs.cache_len as f64;
            assert!((cs.occupancy - expect).abs() < 1e-9, "{cs:?}");
        } else {
            assert!(cs.occupancy <= 1.0 + 1e-9, "{cs:?}");
        }
    }
    // compacted caches save memory vs vanilla
    let (alloc, vanilla, ratio) =
        mod_transformer::serve::kv_cache::memory_savings(&rep.cache_stats);
    assert!(alloc < vanilla, "ratio {ratio}");
}

#[test]
fn decode_always_on_never_skips() {
    let bundle = open("mod_tiny");
    let params = bundle.init_params().unwrap();
    let mut session =
        DecodeSession::new(&bundle, &params, 1, RoutingDecision::AlwaysOn)
            .unwrap();
    let mut tok = BOS as i32;
    for _ in 0..8 {
        session.step(&[tok], &[true]).unwrap();
        tok = 1;
    }
    let rep = session.report();
    assert_eq!(rep.blocks_skipped, 0);
    assert_eq!(rep.blocks_invoked, 4 * 8);
}

#[test]
fn decode_capacity_drops_when_cache_full() {
    let bundle = open("mod_tiny");
    let params = bundle.init_params().unwrap();
    // AlwaysOn routes every token through every block; the routed layers'
    // compacted caches (12 slots here) overflow -> drops (paper §3.1).
    let mut session =
        DecodeSession::new(&bundle, &params, 1, RoutingDecision::AlwaysOn)
            .unwrap();
    let routed_cache = bundle.manifest.cache_len(1).unwrap();
    assert!(routed_cache < 20, "test assumes a small compacted cache");
    let mut tok = BOS as i32;
    for _ in 0..(routed_cache + 8) {
        session.step(&[tok], &[true]).unwrap();
        tok = 2;
    }
    let rep = session.report();
    assert!(rep.capacity_drops > 0, "{rep:?}");
    for cs in &rep.cache_stats {
        if cs.routed {
            assert!((cs.occupancy - 1.0).abs() < 1e-9, "routed cache full");
        }
    }
}

#[test]
fn decode_predictor_decision_runs() {
    let bundle = open("mod_tiny");
    let params = bundle.init_params().unwrap();
    let mut session =
        DecodeSession::new(&bundle, &params, 1, RoutingDecision::Predictor)
            .unwrap();
    let mut tok = BOS as i32;
    for _ in 0..8 {
        let logits = session.step(&[tok], &[true]).unwrap();
        assert!(logits.iter().all(|v| v.is_finite()));
        tok = 3;
    }
    let rep = session.report();
    assert_eq!(rep.steps, 8);
    assert!(rep.blocks_invoked >= 2 * 8);
}

#[test]
fn batched_generation_matches_request_count() {
    let bundle = open("mod_tiny");
    let params = bundle.init_params().unwrap();
    let reqs: Vec<GenerateParams> = (0..3)
        .map(|i| GenerateParams::new(vec![BOS, 5, 10]).max_new(6).seed(i))
        .collect();
    let refs: Vec<&GenerateParams> = reqs.iter().collect();
    let (outs, report) =
        generate_batch(&bundle, &params, 4, RoutingDecision::RouterThreshold,
                       &refs)
            .unwrap();
    assert_eq!(outs.len(), 3);
    for o in &outs {
        assert!(!o.is_empty() && o.len() <= 6);
    }
    assert!(report.tokens_generated > 0);
}

#[test]
fn greedy_batch_rows_match_single_row_decode() {
    // batching must not change a row's output (greedy, same prompt)
    let bundle = open("mod_tiny");
    let params = bundle.init_params().unwrap();
    let req = GenerateParams::new(vec![BOS, 5, 10, 20]).max_new(8);
    let (single, _) = generate_batch(
        &bundle, &params, 1, RoutingDecision::RouterThreshold, &[&req],
    )
    .unwrap();
    let reqs = [req.clone(), req.clone(), req.clone(), req];
    let refs: Vec<&GenerateParams> = reqs.iter().collect();
    let (batched, _) = generate_batch(
        &bundle, &params, 4, RoutingDecision::RouterThreshold, &refs,
    )
    .unwrap();
    for row in &batched {
        assert_eq!(row, &single[0], "batching changed greedy output");
    }
}

#[test]
fn engine_round_trip() {
    let bundle = open("mod_tiny");
    let params = Arc::new(bundle.init_params().unwrap());
    let engine = Engine::start(
        bundle.clone(),
        params,
        ServeConfig::default(),
        RoutingDecision::RouterThreshold,
    )
    .unwrap();
    let gens: Vec<_> = (0..3)
        .map(|i| {
            engine
                .submit(GenerateParams::new(vec![BOS, 3]).max_new(4).seed(i))
                .unwrap()
        })
        .collect();
    for g in gens {
        let resp = g.wait().expect("response");
        assert!(!resp.tokens.is_empty());
    }
    let stats = engine.shutdown();
    assert_eq!(stats.submitted, 3);
    assert_eq!(stats.completed, 3);
}

/// Streamed-vs-blocking determinism: for the same requests (seeds
/// included), the `Generation` event stream concatenates bitwise-equal to
/// `wait().tokens` and to a direct `generate_batch` run — at pool widths
/// 1 and 4 (acceptance: streamed output is bitwise-identical to blocking
/// output at `RP_THREADS ∈ {1,4}`).
#[test]
fn streamed_output_matches_blocking_and_generate_batch() {
    let bundle = open("mod_tiny");
    let params = bundle.init_params().unwrap();
    let decision = RoutingDecision::RouterThreshold;
    let reqs: Vec<GenerateParams> = (0..3)
        .map(|i| {
            GenerateParams::new(vec![BOS, 5 + i as u16, 10])
                .max_new(8)
                .temperature(0.8)
                .top_k(8)
                .seed(100 + i)
        })
        .collect();
    let refs: Vec<&GenerateParams> = reqs.iter().collect();
    let _guard = pool::knob_guard();
    for width in [1usize, 4] {
        pool::with_threads(width, || {
            let (direct, _) =
                generate_batch(&bundle, &params, 4, decision, &refs).unwrap();

            let engine = Engine::start(
                bundle.clone(),
                Arc::new(params.clone()),
                ServeConfig { workers: 1, ..Default::default() },
                decision,
            )
            .unwrap();
            let streamed: Vec<Vec<u16>> = reqs
                .iter()
                .map(|r| {
                    let mut g = engine.submit(r.clone()).unwrap();
                    let mut toks = Vec::new();
                    while let Some(ev) = g.next_event() {
                        match ev {
                            Event::Token { token, index } => {
                                assert_eq!(index, toks.len());
                                toks.push(token);
                            }
                            Event::Done(_) => {}
                            Event::Error(e) => panic!("stream failed: {e}"),
                        }
                    }
                    toks
                })
                .collect();
            let waited: Vec<Vec<u16>> = reqs
                .iter()
                .map(|r| engine.generate(r.clone()).unwrap().tokens)
                .collect();
            engine.shutdown();

            assert_eq!(
                streamed, direct,
                "streamed != generate_batch at width {width}"
            );
            assert_eq!(
                waited, direct,
                "wait() != generate_batch at width {width}"
            );
        });
    }
}

/// Continuous admission: a late request joins an *in-flight* session —
/// a finished row is released (KV slots freed) and re-seated while the
/// other rows keep decoding, with the session's step counter never
/// resetting (no drain bubble; `mid_session_admissions` is the proof).
#[test]
fn engine_admits_mid_flight_and_recycles_rows() {
    let bundle = open("mod_tiny");
    let params = Arc::new(bundle.init_params().unwrap());
    let engine = Engine::start(
        bundle.clone(),
        params,
        ServeConfig { workers: 1, ..Default::default() },
        RoutingDecision::RouterThreshold,
    )
    .unwrap();
    // 6 requests onto one 4-row session. Request 0 is short; requests
    // 1..=3 prefill an 8-token prompt and then decode 16 tokens, so they
    // are still mid-flight (prefill alone outlives request 0) when the
    // queued requests 4 and 5 take over request 0's released row.
    let long_prompt = vec![BOS, 1, 2, 3, 4, 5, 6, 7];
    let reqs = vec![
        GenerateParams::new(vec![BOS, 7]).max_new(2).seed(0),
        GenerateParams::new(long_prompt.clone()).max_new(16).seed(1),
        GenerateParams::new(long_prompt.clone()).max_new(16).seed(2),
        GenerateParams::new(long_prompt).max_new(16).seed(3),
        GenerateParams::new(vec![BOS, 9]).max_new(2).seed(4),
        GenerateParams::new(vec![BOS, 11]).max_new(2).seed(5),
    ];
    let limits: Vec<usize> = reqs.iter().map(|r| r.max_new).collect();
    let gens: Vec<_> =
        reqs.into_iter().map(|r| engine.submit(r).unwrap()).collect();
    for (i, g) in gens.into_iter().enumerate() {
        let resp = g.wait().expect("response");
        assert!(
            !resp.tokens.is_empty() && resp.tokens.len() <= limits[i],
            "req {i}: {:?}",
            resp.tokens
        );
    }
    let stats = engine.shutdown();
    assert_eq!(stats.completed, 6);
    assert_eq!(stats.sessions, 1, "one persistent session served all six");
    assert!(stats.rows_released >= 6, "{stats:?}");
    assert!(
        stats.mid_session_admissions >= 1,
        "no request was admitted mid-flight: {stats:?}"
    );
    assert!(stats.steps > 0);
}

/// Cancellation frees the row mid-decode and a queued request (on a
/// single-row session, so it *needs* that row) completes.
#[test]
fn cancel_frees_row_and_queued_request_completes() {
    let bundle = Arc::new(
        Bundle::native(
            "cancel_tiny",
            &test_model(),
            &test_train(),
            &SyntheticSpec {
                seed: 7,
                decode_batches: vec![1],
                max_decode_len: MAX_DECODE,
                ..Default::default()
            },
        )
        .unwrap(),
    );
    let params = Arc::new(bundle.init_params().unwrap());
    let engine = Engine::start(
        bundle.clone(),
        params,
        ServeConfig { decode_batches: vec![1], workers: 1, ..Default::default() },
        RoutingDecision::RouterThreshold,
    )
    .unwrap();
    // A would occupy the only row for up to ~60 steps
    let mut a = engine
        .submit(
            GenerateParams::new(vec![BOS, 3])
                .max_new(MAX_DECODE - 2)
                .temperature(0.9)
                .seed(1),
        )
        .unwrap();
    let b = engine
        .submit(GenerateParams::new(vec![BOS, 5]).max_new(4).seed(2))
        .unwrap();
    // wait until A is demonstrably mid-decode, then cancel it
    match a.next_event() {
        Some(Event::Token { .. }) => {}
        other => panic!("expected a first token, got {other:?}"),
    }
    a.cancel();
    // cancellation is best-effort (checked at each step's input pass);
    // with ~60 steps left it wins in practice, but a starved test thread
    // could legally lose the race to natural completion — accept either
    // terminal, and when it IS an error it must be the typed Cancelled
    let a_cancelled = match a.wait() {
        Err(e) => {
            assert!(e.to_string().contains("cancelled"), "wrong error: {e}");
            true
        }
        Ok(resp) => {
            assert!(resp.tokens.len() <= MAX_DECODE - 2);
            false
        }
    };
    // either way the single row was freed: queued B completes
    let resp = b.wait().expect("queued request must complete after cancel");
    assert!(!resp.tokens.is_empty());
    let stats = engine.shutdown();
    if a_cancelled {
        assert_eq!(stats.cancelled, 1, "{stats:?}");
        assert_eq!(stats.completed, 1, "{stats:?}");
    } else {
        assert_eq!(stats.completed, 2, "{stats:?}");
    }
    assert!(stats.rows_released >= 2, "{stats:?}");
}

/// A single-row bundle for admission-control tests: one session row, so
/// service order is exactly the scheduler's pop order and an in-flight
/// request pins every queued one.
fn single_row_engine(name: &str, queue_cap: usize) -> (Arc<Bundle>, Engine) {
    let bundle = Arc::new(
        Bundle::native(
            name,
            &test_model(),
            &test_train(),
            &SyntheticSpec {
                seed: 7,
                decode_batches: vec![1],
                max_decode_len: MAX_DECODE,
                ..Default::default()
            },
        )
        .unwrap(),
    );
    let params = Arc::new(bundle.init_params().unwrap());
    let engine = Engine::start(
        bundle.clone(),
        params,
        ServeConfig {
            decode_batches: vec![1],
            workers: 1,
            queue_cap,
            ..Default::default()
        },
        RoutingDecision::RouterThreshold,
    )
    .unwrap();
    (bundle, engine)
}

/// Admission control: with the only row occupied and the queue at its
/// cap, the next submit sheds synchronously with the typed `Overloaded`
/// kind, a computed Retry-After, a flight-ring record, and per-class
/// shed accounting — while already-admitted requests are untouched.
#[test]
fn queue_overflow_sheds_typed_overloaded_with_retry_after() {
    let (_bundle, engine) = single_row_engine("overload_tiny", 1);
    // A occupies the only row (first token proves it left the queue)
    let mut a = engine
        .submit(
            GenerateParams::new(vec![BOS, 3])
                .max_new(MAX_DECODE - 2)
                .temperature(0.9)
                .seed(1),
        )
        .unwrap();
    match a.next_event() {
        Some(Event::Token { .. }) => {}
        other => panic!("expected a first token, got {other:?}"),
    }
    // B fills the whole queue (cap 1) ...
    let b = engine
        .submit(GenerateParams::new(vec![BOS, 5]).max_new(2).seed(2))
        .unwrap();
    // ... so C must shed, typed, without ever entering the queue
    let err = engine
        .submit_typed(
            GenerateParams::new(vec![BOS, 7])
                .max_new(2)
                .seed(3)
                .priority(Priority::Bulk),
        )
        .expect_err("overflow must shed");
    assert_eq!(err.kind, ServeErrorKind::Overloaded);
    assert!(err.message.contains("queue full"), "{err}");
    let secs = err
        .retry_after_secs()
        .expect("overload carries a computed Retry-After");
    assert!(secs >= 1, "Retry-After rounds up to at least 1s, got {secs}");
    // the shed is visible at the flight recorder with zeroed decode state
    let rec = engine
        .recent_traces()
        .into_iter()
        .find(|r| r.outcome == "overloaded")
        .expect("shed request recorded in the flight ring");
    assert_eq!(rec.decode_tokens, 0);
    // admitted requests are unaffected by the shed
    a.cancel();
    let _ = a.wait();
    let resp = b.wait().expect("queued request still completes");
    assert!(!resp.tokens.is_empty());
    let stats = engine.shutdown();
    assert_eq!(stats.shed(), 1, "{stats:?}");
    assert_eq!(stats.classes[Priority::Bulk.index()].shed, 1, "{stats:?}");
    assert_eq!(stats.completed, 1, "{stats:?}");
}

/// Weighted fair share, end to end: an interactive request submitted
/// *after* a bulk backlog is still served first (class weight 8 vs 1),
/// and the bulk backlog is not starved — every bulk request completes.
/// Queue latencies prove the order without racing on thread wakeups:
/// on a single row, admission is strictly sequential, so the last-in
/// interactive request beating the backlog means a smaller queue wait.
#[test]
fn interactive_requests_jump_the_bulk_backlog_without_starving_it() {
    let (_bundle, engine) = single_row_engine("fairshare_tiny", 0);
    let mut a = engine
        .submit(
            GenerateParams::new(vec![BOS, 3])
                .max_new(MAX_DECODE - 2)
                .temperature(0.9)
                .seed(1),
        )
        .unwrap();
    match a.next_event() {
        Some(Event::Token { .. }) => {}
        other => panic!("expected a first token, got {other:?}"),
    }
    // bulk backlog first, the interactive request arrives LAST
    let bulks: Vec<_> = (0..4)
        .map(|i| {
            engine
                .submit(
                    GenerateParams::new(vec![BOS, 5 + i as u16])
                        .max_new(2)
                        .seed(10 + i as u64)
                        .priority(Priority::Bulk),
                )
                .unwrap()
        })
        .collect();
    let inter = engine
        .submit(
            GenerateParams::new(vec![BOS, 2])
                .max_new(2)
                .seed(99)
                .priority(Priority::Interactive),
        )
        .unwrap();
    a.cancel();
    let _ = a.wait();
    let inter_resp = inter.wait().expect("interactive completes");
    let bulk_waits: Vec<std::time::Duration> = bulks
        .into_iter()
        .map(|g| g.wait().expect("bulk completes").queue_latency)
        .collect();
    // submitted last, admitted first: strictly less time in the queue
    // than every bulk request that was already waiting
    for (i, w) in bulk_waits.iter().enumerate() {
        assert!(
            inter_resp.queue_latency < *w,
            "bulk {i} ({w:?}) was served before interactive \
             ({:?})",
            inter_resp.queue_latency
        );
    }
    let stats = engine.shutdown();
    assert_eq!(
        stats.classes[Priority::Interactive.index()].completed,
        1,
        "{stats:?}"
    );
    assert_eq!(
        stats.classes[Priority::Bulk.index()].completed,
        4,
        "bulk starved: {stats:?}"
    );
}

/// A request cancelled while still queued lands in the flight ring as a
/// queue-side `cancelled` record with zeroed decode fields — abandoning
/// a stream before admission must not vanish from observability.
#[test]
fn flight_ring_records_queue_side_cancellation() {
    let (_bundle, engine) = single_row_engine("queue_cancel_tiny", 0);
    let mut a = engine
        .submit(
            GenerateParams::new(vec![BOS, 3])
                .max_new(MAX_DECODE - 2)
                .temperature(0.9)
                .seed(1),
        )
        .unwrap();
    match a.next_event() {
        Some(Event::Token { .. }) => {}
        other => panic!("expected a first token, got {other:?}"),
    }
    // B: 4-token prompt (distinguishes its flight record from A's)
    let mut b = engine
        .submit(GenerateParams::new(vec![BOS, 5, 6, 7]).max_new(2).seed(2))
        .unwrap();
    b.cancel();
    let err = b.wait().expect_err("cancelled while queued");
    assert!(err.to_string().contains("cancelled"), "{err}");
    a.cancel();
    let _ = a.wait();
    let rec = engine
        .recent_traces()
        .into_iter()
        .find(|r| r.outcome == "cancelled" && r.prompt_tokens == 4)
        .expect("queue-side cancellation recorded in the flight ring");
    assert_eq!(rec.decode_tokens, 0, "never reached a row");
    assert!(rec.trace.queue_ms >= 0.0);
    engine.shutdown();
}

/// Priority changes only WHEN a request is admitted, never its content:
/// a mixed-class batch through the engine is bitwise-identical to the
/// synchronous `generate_batch` baseline at pool widths 1 and 4.
#[test]
fn priority_classes_change_order_not_tokens() {
    let bundle = open("mod_tiny");
    let params = bundle.init_params().unwrap();
    let decision = RoutingDecision::RouterThreshold;
    let classes =
        [Priority::Bulk, Priority::Interactive, Priority::Normal];
    let reqs: Vec<GenerateParams> = (0..3)
        .map(|i| {
            GenerateParams::new(vec![BOS, 5 + i as u16, 10])
                .max_new(8)
                .temperature(0.8)
                .top_k(8)
                .seed(100 + i as u64)
                .priority(classes[i])
        })
        .collect();
    let refs: Vec<&GenerateParams> = reqs.iter().collect();
    let _guard = pool::knob_guard();
    for width in [1usize, 4] {
        pool::with_threads(width, || {
            let (direct, _) =
                generate_batch(&bundle, &params, 4, decision, &refs).unwrap();
            let engine = Engine::start(
                bundle.clone(),
                Arc::new(params.clone()),
                ServeConfig {
                    workers: 1,
                    queue_cap: 8,
                    ..Default::default()
                },
                decision,
            )
            .unwrap();
            let served: Vec<Vec<u16>> = reqs
                .iter()
                .map(|r| engine.generate(r.clone()).unwrap().tokens)
                .collect();
            engine.shutdown();
            assert_eq!(
                served, direct,
                "priority changed token content at width {width}"
            );
        });
    }
}

/// Regression (old bug): a failed batch dropped the responders, so
/// callers saw only "request dropped (batch failed?)" while the real
/// cause went to stderr. The cause must now arrive typed, per-request —
/// and the worker must survive the failed step and keep answering.
#[test]
fn batch_failure_delivers_typed_error_with_cause() {
    // a bundle with routed layers but no predictor params: asking the
    // engine to route by Predictor makes every decode step fail at the
    // first routed block — a genuine mid-step session failure
    let model = ModelConfig { train_predictor: false, ..test_model() };
    let bundle = Arc::new(
        Bundle::native(
            "nopred_tiny",
            &model,
            &test_train(),
            &SyntheticSpec {
                seed: 7,
                decode_batches: vec![1, 4],
                max_decode_len: MAX_DECODE,
                ..Default::default()
            },
        )
        .unwrap(),
    );
    let params = Arc::new(bundle.init_params().unwrap());
    let engine = Engine::start(
        bundle.clone(),
        params,
        ServeConfig { workers: 1, ..Default::default() },
        RoutingDecision::Predictor,
    )
    .unwrap();
    let err = engine
        .submit(GenerateParams::new(vec![BOS, 3]).max_new(4))
        .unwrap()
        .wait()
        .expect_err("predictor routing without params must fail");
    let msg = err.to_string();
    assert!(msg.contains("batch_failed"), "kind lost: {msg}");
    assert!(msg.contains("predictor"), "cause lost: {msg}");
    // the worker survived the failed step: the next request gets the
    // same typed answer (no hang, no silent drop)
    let err2 = engine
        .submit(GenerateParams::new(vec![BOS, 5]).max_new(4))
        .unwrap()
        .wait()
        .expect_err("second request must also fail typed");
    assert!(err2.to_string().contains("batch_failed"), "{err2}");
    let stats = engine.shutdown();
    assert_eq!(stats.failed, 2);
    assert_eq!(stats.completed, 0);
}

/// Structurally invalid requests are rejected synchronously at submit,
/// scoped to the offending request — an out-of-vocab prompt must never
/// reach the shared session where it would fail innocent batchmates.
#[test]
fn submit_rejects_invalid_requests_typed() {
    let bundle = open("mod_tiny");
    let params = Arc::new(bundle.init_params().unwrap());
    let engine = Engine::start(
        bundle.clone(),
        params,
        ServeConfig { workers: 1, ..Default::default() },
        RoutingDecision::RouterThreshold,
    )
    .unwrap();
    let must_reject = |p: GenerateParams| match engine.submit(p) {
        Err(e) => e.to_string(),
        Ok(_) => panic!("expected a submit-time rejection"),
    };
    // token 9999 is outside the 259-token vocab
    let msg = must_reject(GenerateParams::new(vec![BOS, 9999]).max_new(4));
    assert!(msg.contains("rejected"), "{msg}");
    assert!(msg.contains("9999"), "offending token lost: {msg}");
    // zero budget
    let msg = must_reject(GenerateParams::new(vec![BOS]).max_new(0));
    assert!(msg.contains("rejected"), "{msg}");
    // over the decode budget
    let msg = must_reject(GenerateParams::new(vec![BOS]).max_new(MAX_DECODE * 2));
    assert!(msg.contains("rejected"), "{msg}");
    // a healthy request still flows
    let ok = engine
        .generate(GenerateParams::new(vec![BOS, 3]).max_new(4))
        .expect("healthy request must still be served");
    assert!(!ok.tokens.is_empty());
    engine.shutdown();
}

/// An already-expired deadline fails typed (queue-side enforcement).
#[test]
fn expired_deadline_fails_typed() {
    let bundle = open("mod_tiny");
    let params = Arc::new(bundle.init_params().unwrap());
    let engine = Engine::start(
        bundle.clone(),
        params,
        ServeConfig { workers: 1, ..Default::default() },
        RoutingDecision::RouterThreshold,
    )
    .unwrap();
    let err = engine
        .submit(GenerateParams::new(vec![BOS]).max_new(4).deadline_ms(0))
        .unwrap()
        .wait()
        .expect_err("zero deadline must expire");
    assert!(err.to_string().contains("deadline_exceeded"), "{err}");
    let stats = engine.shutdown();
    assert_eq!(stats.deadline_exceeded, 1);
}

/// Stop tokens end the stream early (EOS-style: the stop token is the
/// last emitted token).
#[test]
fn stop_tokens_end_generation_early() {
    let bundle = open("mod_tiny");
    let params = Arc::new(bundle.init_params().unwrap());
    let engine = Engine::start(
        bundle.clone(),
        params,
        ServeConfig { workers: 1, ..Default::default() },
        RoutingDecision::RouterThreshold,
    )
    .unwrap();
    let base = engine
        .generate(GenerateParams::new(vec![BOS, 5]).max_new(6))
        .unwrap();
    assert!(!base.tokens.is_empty());
    let first = base.tokens[0];
    if first != EOS {
        let stopped = engine
            .generate(
                GenerateParams::new(vec![BOS, 5]).max_new(6).stop_token(first),
            )
            .unwrap();
        assert_eq!(stopped.tokens, vec![first], "greedy stream must stop");
    }
    engine.shutdown();
}

/// With several engine workers, persistent sessions overlap on separate
/// threads; every request completes, and greedy outputs are independent
/// of which worker/row served them (same prompt ⇒ same tokens).
#[test]
fn engine_overlapping_workers_serve_all_requests() {
    let bundle = open("mod_tiny");
    let params = Arc::new(bundle.init_params().unwrap());
    let engine = Engine::start(
        bundle.clone(),
        params,
        ServeConfig { workers: 3, ..Default::default() },
        RoutingDecision::RouterThreshold,
    )
    .unwrap();
    let gens: Vec<_> = (0..9)
        .map(|i| {
            engine
                .submit(
                    GenerateParams::new(vec![BOS, 7, 2]).max_new(12).seed(i),
                )
                .unwrap()
        })
        .collect();
    let outputs: Vec<Vec<u16>> = gens
        .into_iter()
        .map(|g| g.wait().expect("response").tokens)
        .collect();
    assert_eq!(outputs.len(), 9);
    for o in &outputs {
        assert!(!o.is_empty() && o.len() <= 12);
        // greedy + identical prompt: every worker must emit the same tokens
        assert_eq!(o, &outputs[0], "worker-dependent greedy output");
    }
    let stats = engine.shutdown();
    assert_eq!(stats.completed, 9);
    // overlap, observed: 9 requests across 3 idle workers — at least two
    // sessions decode at once where parallel execution is physically
    // possible (on a single hardware thread the OS may legitimately run
    // each session to completion before scheduling the next worker).
    let cores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    if cores >= 2 {
        assert!(
            stats.peak_active_workers >= 2,
            "sessions never overlapped: {stats:?}"
        );
    }
}

/// The tentpole's session-level contract, directly: a row is released and
/// re-admitted *mid-flight* and its decode is bitwise-identical to the
/// same request in a fresh session, while the session's step counter
/// keeps advancing (never resets) and the neighbouring row is untouched.
#[test]
fn session_release_admit_reseats_row_bitwise() {
    let bundle = open("mod_tiny");
    let params = bundle.init_params().unwrap();
    let decision = RoutingDecision::RouterThreshold;
    let vocab = bundle.manifest.model.vocab_size;

    // reference: request B decoded greedily in row 0 of a fresh session
    let mut fresh = DecodeSession::new(&bundle, &params, 4, decision).unwrap();
    let mut ref_logits: Vec<Vec<f32>> = Vec::new();
    let mut tok = BOS as i32;
    for _ in 0..10 {
        let mut toks = vec![PAD as i32; 4];
        toks[0] = tok;
        let l = fresh
            .step(&toks, &[true, false, false, false])
            .unwrap();
        ref_logits.push(l[..vocab].to_vec());
        tok = argmax(&l[..vocab]) as i32;
    }

    // recycled: rows 0 and 1 decode request A for 7 steps, then row 0 is
    // released + re-admitted and decodes request B while row 1 continues
    let mut s = DecodeSession::new(&bundle, &params, 4, decision).unwrap();
    let mut a0 = BOS as i32;
    let mut a1 = BOS as i32;
    for _ in 0..7 {
        let mut toks = vec![PAD as i32; 4];
        toks[0] = a0;
        toks[1] = a1;
        let l = s.step(&toks, &[true, true, false, false]).unwrap();
        a0 = argmax(&l[..vocab]) as i32;
        a1 = argmax(&l[vocab..2 * vocab]) as i32;
    }
    let steps_before = s.report().steps;
    s.release_row(0).unwrap();
    s.admit_row(0).unwrap();
    let mut tok = BOS as i32;
    for (i, expected) in ref_logits.iter().enumerate() {
        let mut toks = vec![PAD as i32; 4];
        toks[0] = tok;
        toks[1] = a1;
        let l = s.step(&toks, &[true, true, false, false]).unwrap();
        assert_eq!(
            &l[..vocab],
            expected.as_slice(),
            "recycled row 0 diverged from a fresh session at step {i}"
        );
        tok = argmax(&l[..vocab]) as i32;
        a1 = argmax(&l[vocab..2 * vocab]) as i32;
    }
    assert_eq!(
        s.report().steps,
        steps_before + 10,
        "session step counter must keep advancing across release/admit"
    );
}

#[test]
fn trainer_rejects_mismatched_data_shape() {
    let bundle = open("mod_tiny");
    let bad = BatchIter::new(
        MarkovCorpus::new(CorpusSpec::default(), 7),
        2, // wrong batch size
        bundle.manifest.model.seq_len,
    );
    assert!(Trainer::new(bundle.clone(), bad, None).is_err());
}

#[test]
fn checkpoint_format_roundtrips_through_abi() {
    // MODCKPT written by the coordinator reloads into the exact same
    // ABI-ordered tensors (the same codec python reads/writes).
    let bundle = open("mod_tiny");
    let params = bundle.init_params().unwrap();
    let named = bundle.named_params(&params);
    let dir = std::env::temp_dir().join("mod_ckpt_interop");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("resaved.ckpt");
    checkpoint::save(&path, &named).unwrap();
    let back = checkpoint::load(&path).unwrap();
    let reordered = bundle.order_params(back).unwrap();
    assert_eq!(reordered, params);
}

#[test]
fn full_run_writes_metrics_and_checkpoint() {
    let bundle = open("mod_tiny");
    let dir = std::env::temp_dir().join("mod_full_run_test");
    let _ = std::fs::remove_dir_all(&dir);
    let mut trainer =
        Trainer::new(bundle.clone(), data_for(&bundle, 7), None).unwrap();
    let outcome = trainer
        .run(&TrainerOptions {
            steps: Some(3),
            log_every: 1,
            ckpt_every: 0,
            run_dir: dir.clone(),
            resume: None,
        })
        .unwrap();
    assert!(outcome.metrics_path.exists());
    assert!(outcome.ckpt_path.exists());
    let rows =
        mod_transformer::coordinator::metrics::load_jsonl(&outcome.metrics_path)
            .unwrap();
    assert_eq!(rows.len(), 3);
    assert!(dir.join("metrics.csv").exists());
}

/// Fig-7 coverage: expert-choice MoE, staged MoDE (MoD routing around MoE
/// blocks) and integrated MoDE (no-op expert) all train, evaluate and
/// decode natively — no pjrt feature, no artifacts, no skips.
#[test]
fn moe_and_mode_train_eval_decode_natively() {
    let cases: &[(&str, FfMode, RoutingMode)] = &[
        ("moe_tiny", FfMode::Moe, RoutingMode::None),
        ("mode_staged_tiny", FfMode::Moe, RoutingMode::ModInterleaved),
        ("mode_integrated_tiny", FfMode::ModeIntegrated, RoutingMode::None),
    ];
    for &(name, ff_mode, routing) in cases {
        let model = ModelConfig {
            ff_mode,
            routing,
            n_experts: 2,
            expert_capacity_frac: 0.25,
            train_predictor: routing != RoutingMode::None,
            ..test_model()
        };
        let bundle = Arc::new(
            Bundle::native(
                name,
                &model,
                &test_train(),
                &SyntheticSpec {
                    seed: 7,
                    decode_batches: vec![1],
                    max_decode_len: MAX_DECODE,
                    ..Default::default()
                },
            )
            .expect("synthetic MoE bundle"),
        );
        assert_eq!(bundle.manifest.n_params, model.n_params(), "{name}");

        // train: finite metrics, loss actually improves
        let mut trainer =
            Trainer::new(bundle.clone(), data_for(&bundle, 7), None).unwrap();
        let mut first_ce = f32::NAN;
        let mut last_ce = f32::NAN;
        for s in 0..15 {
            let m = trainer
                .train_one(&data_for(&bundle, 7).batch_at(s))
                .unwrap();
            assert!(m.iter().all(|v| v.is_finite()), "{name}: {m:?}");
            if s == 0 {
                first_ce = m[1];
            }
            last_ce = m[1];
        }
        assert!(
            last_ce < first_ce,
            "{name}: ce did not improve ({first_ce} -> {last_ce})"
        );

        // eval: every routing mode runs on the MoE forward
        for mode in ["topk", "router"] {
            let e = trainer.evaluate(mode, 1).expect(mode);
            assert!(e.ce.is_finite() && e.ce > 0.0, "{name}/{mode}: {e:?}");
        }

        // decode: the layer-sliced MoE block step produces finite logits
        let params = trainer.params().unwrap();
        let mut session = DecodeSession::new(
            &bundle, &params, 1, RoutingDecision::RouterThreshold,
        )
        .unwrap();
        let mut tok = BOS as i32;
        for _ in 0..16 {
            let logits = session.step(&[tok], &[true]).unwrap();
            assert!(
                logits.iter().all(|v| v.is_finite()),
                "{name}: non-finite decode logits"
            );
            tok = logits
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.total_cmp(b.1))
                .unwrap()
                .0 as i32;
        }
        let rep = session.report();
        assert_eq!(rep.steps, 16, "{name}");
        assert!(rep.total_flops > 0.0, "{name}");
    }
}

#[test]
fn vanilla_bundle_decodes_without_routing() {
    // a no-routing config: every cache full-length, nothing skipped
    let model = ModelConfig {
        routing: RoutingMode::None,
        train_predictor: false,
        ..test_model()
    };
    let bundle = Arc::new(
        Bundle::native(
            "baseline_tiny",
            &model,
            &test_train(),
            &SyntheticSpec {
                seed: 7,
                decode_batches: vec![1, 4],
                max_decode_len: MAX_DECODE,
                ..Default::default()
            },
        )
        .unwrap(),
    );
    assert!(bundle.manifest.routed_layers.is_empty());
    let params = bundle.init_params().unwrap();
    let mut session =
        DecodeSession::new(&bundle, &params, 1, RoutingDecision::RouterThreshold)
            .unwrap();
    for t in 0..6 {
        let logits = session.step(&[t as i32 + 1], &[true]).unwrap();
        assert!(logits.iter().all(|v| v.is_finite()));
    }
    let rep = session.report();
    assert_eq!(rep.blocks_skipped, 0);
    let (alloc, vanilla, ratio) =
        mod_transformer::serve::kv_cache::memory_savings(&rep.cache_stats);
    assert_eq!(alloc, vanilla);
    assert!((ratio - 1.0).abs() < 1e-12);
}

/// Satellite acceptance: the depth×time compute ledger reconciles
/// exactly. Per engine, the per-layer `[invoked, skipped]` pairs sum to
/// the aggregate block counters; globally, the `mod_layer_tokens_total`
/// family carries the same cumulative totals as
/// `engine_blocks_{invoked,skipped}_total` — both sides are incremented
/// from the identical per-report deltas in one absorb block.
#[test]
fn mod_layer_ledger_reconciles_with_block_totals() {
    use mod_transformer::util::json::Json;
    use mod_transformer::util::metrics;

    // read the global registry: (sum over mod_layer series, engine pair)
    let read = || {
        let snap = metrics::snapshot_json();
        let mut layers = [0u64; 2];
        let mut engine_totals = [0u64; 2];
        if let Some(Json::Obj(entries)) = snap.get("metrics") {
            for (key, v) in entries {
                let val = v.as_u64().unwrap_or(0);
                if key.starts_with("mod_layer_tokens_total{") {
                    if key.contains("path=\"invoked\"") {
                        layers[0] += val;
                    } else if key.contains("path=\"skipped\"") {
                        layers[1] += val;
                    }
                } else if key == "engine_blocks_invoked_total" {
                    engine_totals[0] = val;
                } else if key == "engine_blocks_skipped_total" {
                    engine_totals[1] = val;
                }
            }
        }
        (layers, engine_totals)
    };
    let (_, before_engine) = read();

    let bundle = open("mod_tiny");
    let n_layers = bundle.manifest.model.n_layers;
    let params = Arc::new(bundle.init_params().unwrap());
    let engine = Engine::start(
        bundle.clone(),
        params,
        ServeConfig { workers: 1, ..Default::default() },
        RoutingDecision::RouterThreshold,
    )
    .unwrap();
    let gens: Vec<_> = (0..4)
        .map(|i| {
            engine
                .submit(
                    GenerateParams::new(vec![BOS, 5 + i as u16])
                        .max_new(8)
                        .seed(i as u64),
                )
                .unwrap()
        })
        .collect();
    for g in gens {
        g.wait().expect("response");
    }
    let stats = engine.shutdown();

    // per-engine: the depth axis sums to the aggregates, exactly
    assert!(stats.blocks_invoked > 0 && stats.blocks_skipped > 0, "{stats:?}");
    assert_eq!(stats.layer_blocks.len(), n_layers, "{stats:?}");
    let sum_inv: u64 = stats.layer_blocks.iter().map(|lb| lb[0]).sum();
    let sum_skip: u64 = stats.layer_blocks.iter().map(|lb| lb[1]).sum();
    assert_eq!(sum_inv, stats.blocks_invoked, "{stats:?}");
    assert_eq!(sum_skip, stats.blocks_skipped, "{stats:?}");
    // unrouted layers run dense: every dispatch invoked, none skipped
    for (li, lb) in stats.layer_blocks.iter().enumerate() {
        if !bundle.manifest.model.is_routed_block(li) {
            assert_eq!(lb[1], 0, "full layer {li} skipped: {stats:?}");
            assert!(lb[0] > 0, "full layer {li} idle: {stats:?}");
        }
    }

    // global registry: concurrent tests' engines may be mid-absorb at
    // any single sampling instant, so poll for a quiescent read — at
    // every such instant the cumulative families are exactly equal
    let mut agreed = None;
    for _ in 0..200 {
        let (layers, engine_totals) = read();
        if layers == engine_totals {
            agreed = Some(engine_totals);
            break;
        }
        std::thread::sleep(std::time::Duration::from_millis(25));
    }
    let totals = agreed.expect(
        "mod_layer_tokens_total sums never reconciled with \
         engine_blocks_{invoked,skipped}_total",
    );
    // and our own traffic is included in both families
    assert!(
        totals[0] >= before_engine[0] + stats.blocks_invoked
            && totals[1] >= before_engine[1] + stats.blocks_skipped,
        "ledger lost traffic: {totals:?} vs {before_engine:?} + {stats:?}"
    );
}

/// Satellite: the step trace must describe row 0's *current* step only.
/// A step where row 0 is inactive leaves the trace empty instead of
/// recording row 0's stale gate values as if it had participated.
#[test]
fn step_trace_is_gated_on_row_zero_activity() {
    let bundle = open("mod_tiny");
    let params = bundle.init_params().unwrap();
    let mut session =
        DecodeSession::new(&bundle, &params, 4, RoutingDecision::RouterThreshold)
            .unwrap();
    let routed = bundle.manifest.routed_layers.len();
    assert!(routed > 0, "test model must have routed layers");

    // row 0 active: the trace covers every routed layer
    let tr = session
        .step_traced(&[BOS as i32, BOS as i32, 0, 0], &[true, true, false, false])
        .unwrap();
    assert_eq!(tr.routed.len(), routed, "{tr:?}");

    // row 0 inactive: the step ran (row 1 decoded) but the trace is empty
    let tr = session
        .step_traced(&[0, 7, 0, 0], &[false, true, false, false])
        .unwrap();
    assert!(tr.routed.is_empty(), "inactive row 0 must not be traced: {tr:?}");
}

/// Tentpole acceptance: a prefix-cache-hit request streams bitwise
/// identically to its cold run while skipping the cached chunks' work —
/// proven via counters (`prefill_tokens` drops by exactly the reused
/// tokens; `blocks_invoked` for cold+warm is strictly below 2× cold) —
/// at pool widths 1 and 4.
#[test]
fn warm_prefix_hit_matches_cold_bitwise_and_skips_cached_chunks() {
    let bundle = open("mod_tiny");
    let params = bundle.init_params().unwrap();
    let decision = RoutingDecision::RouterThreshold;
    // 9-token prompt over 4-token pages: chunks [0..4) and [4..8) are
    // cacheable, the final token always runs live (its logits seed the
    // first sampled token)
    let prompt = vec![BOS, 3, 1, 4, 1, 5, 9, 2, 6];
    let req = GenerateParams::new(prompt.clone())
        .max_new(6)
        .temperature(0.8)
        .top_k(8)
        .seed(77);
    let cfg = || ServeConfig {
        workers: 1,
        prefill_chunk: 4,
        prefix_cache_bytes: 1 << 20,
        ..Default::default()
    };
    let _guard = pool::knob_guard();
    for width in [1usize, 4] {
        pool::with_threads(width, || {
            // cold-only baseline engine: per-request block cost
            let engine = Engine::start(
                bundle.clone(),
                Arc::new(params.clone()),
                cfg(),
                decision,
            )
            .unwrap();
            let cold = engine.generate(req.clone()).unwrap().tokens;
            let cold_stats = engine.shutdown();
            assert_eq!(cold_stats.prefix.hits, 0, "{cold_stats:?}");
            assert_eq!(cold_stats.prefill_tokens, prompt.len() as u64);

            // cold + warm on a fresh engine (fresh cache): the second,
            // identical request reuses the first one's pages
            let engine = Engine::start(
                bundle.clone(),
                Arc::new(params.clone()),
                cfg(),
                decision,
            )
            .unwrap();
            let first = engine.generate(req.clone()).unwrap().tokens;
            let warm = engine.generate(req.clone()).unwrap().tokens;
            let stats = engine.shutdown();

            assert_eq!(first, cold, "cold runs diverged at width {width}");
            assert_eq!(
                warm, cold,
                "warm (prefix-hit) stream != cold at width {width}"
            );
            assert!(stats.prefix.hits >= 1, "{stats:?}");
            assert_eq!(
                stats.prefix.tokens_reused, 8,
                "both full pages must seat: {stats:?}"
            );
            // the warm request ingested only the uncached tail
            assert_eq!(
                stats.prefill_tokens,
                2 * prompt.len() as u64 - stats.prefix.tokens_reused,
                "{stats:?}"
            );
            // and the seated chunks' block executions never ran
            assert!(
                stats.blocks_invoked < 2 * cold_stats.blocks_invoked,
                "warm run re-executed cached blocks: {} vs 2*{}",
                stats.blocks_invoked,
                cold_stats.blocks_invoked
            );
        });
    }
}

/// A request that opts out of the prefix cache neither reuses nor
/// publishes pages, and still streams identically.
#[test]
fn prefix_cache_opt_out_stays_cold_and_bitwise_equal() {
    let bundle = open("mod_tiny");
    let params = Arc::new(bundle.init_params().unwrap());
    let prompt = vec![BOS, 2, 7, 1, 8, 2, 8, 1, 8];
    let req = GenerateParams::new(prompt.clone()).max_new(4).seed(5);
    let engine = Engine::start(
        bundle.clone(),
        params,
        ServeConfig {
            workers: 1,
            prefill_chunk: 4,
            prefix_cache_bytes: 1 << 20,
            ..Default::default()
        },
        RoutingDecision::RouterThreshold,
    )
    .unwrap();
    let a = engine
        .generate(req.clone().prefix_cache(false))
        .unwrap()
        .tokens;
    let b = engine
        .generate(req.clone().prefix_cache(false))
        .unwrap()
        .tokens;
    assert_eq!(a, b);
    let stats = engine.shutdown();
    assert_eq!(stats.prefix.hits, 0, "{stats:?}");
    assert_eq!(stats.prefix.inserts, 0, "opt-out published pages: {stats:?}");
    assert_eq!(stats.prefix.pages, 0, "{stats:?}");
}

/// Tentpole acceptance: chunked prefill of a long prompt must not stall
/// concurrent decode rows — short requests queued behind a full batch
/// are admitted and complete while the long prompt is still in flight
/// (`mid_session_admissions > 0` with the long request unfinished at
/// that moment is only possible if prefill interleaves with decode).
#[test]
fn long_prompt_prefill_does_not_stall_decode_rows() {
    let bundle = open("mod_tiny");
    let params = Arc::new(bundle.init_params().unwrap());
    let engine = Engine::start(
        bundle.clone(),
        params,
        ServeConfig {
            workers: 1,
            prefill_chunk: 4,
            ..Default::default()
        },
        RoutingDecision::RouterThreshold,
    )
    .unwrap();
    // 40-token prompt over 4-token chunks = 10 prefill iterations, plus
    // 8 decode steps: the long row outlives several short-request
    // lifetimes on the other three rows of the 4-row session
    let long_prompt: Vec<u16> =
        std::iter::once(BOS).chain((0..39).map(|i| 1 + (i % 200))).collect();
    let long = engine
        .submit(GenerateParams::new(long_prompt).max_new(8).seed(9))
        .unwrap();
    let shorts: Vec<_> = (0..5)
        .map(|i| {
            engine
                .submit(
                    GenerateParams::new(vec![BOS, 5 + i as u16])
                        .max_new(2)
                        .seed(i),
                )
                .unwrap()
        })
        .collect();
    for (i, g) in shorts.into_iter().enumerate() {
        let resp = g.wait().expect("short response");
        assert!(
            !resp.tokens.is_empty() && resp.tokens.len() <= 2,
            "short {i}: {:?}",
            resp.tokens
        );
    }
    let resp = long.wait().expect("long response");
    assert!(!resp.tokens.is_empty() && resp.tokens.len() <= 8);
    assert_eq!(resp.prefill_tokens, 40);
    let stats = engine.shutdown();
    assert_eq!(stats.completed, 6);
    assert!(
        stats.mid_session_admissions > 0,
        "shorts never joined the in-flight session: {stats:?}"
    );
    assert_eq!(stats.prefill_tokens, 40 + 5 * 2, "{stats:?}");
    assert!(stats.prefill_chunks >= 10, "{stats:?}");
}
