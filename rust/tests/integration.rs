//! Integration tests over the real artifacts (require `make artifacts`).
//!
//! Exercises the full L3 stack against the AOT executables: bundle ABI
//! verification, training-step execution + determinism, checkpoint
//! resume, held-out evaluation under all routing modes, the layer-sliced
//! decode runtime (skip semantics, capacity drops, cache accounting), and
//! the batching server. Tests skip gracefully (with a note) when the
//! artifacts are absent so `cargo test` stays useful pre-`make artifacts`.

use std::path::Path;
use std::sync::Arc;

use mod_transformer::config::ServeConfig;
use mod_transformer::coordinator::{checkpoint, Trainer, TrainerOptions};
use mod_transformer::data::{BatchIter, CorpusSpec, MarkovCorpus, BOS};
use mod_transformer::runtime::{Bundle, Engine};
use mod_transformer::serve::batcher::{generate_batch, Request, Server};
use mod_transformer::serve::{DecodeSession, RoutingDecision};

fn open(name: &str) -> Option<Arc<Bundle>> {
    let dir = Path::new("artifacts").join(name);
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: artifacts/{name} missing (run `make artifacts`)");
        return None;
    }
    let engine = Arc::new(Engine::cpu().expect("pjrt cpu client"));
    Some(Arc::new(Bundle::open(engine, &dir).expect("bundle opens")))
}

fn data_for(bundle: &Arc<Bundle>, seed: u64) -> BatchIter {
    BatchIter::new(
        MarkovCorpus::new(CorpusSpec::default(), seed),
        bundle.manifest.train.batch_size,
        bundle.manifest.model.seq_len,
    )
}

#[test]
fn bundle_abi_is_consistent() {
    let Some(bundle) = open("mod_tiny") else { return };
    let m = &bundle.manifest;
    // rust-side param accounting matches the python-side manifest
    assert_eq!(m.model.n_params(), m.n_params);
    // every routed layer has a compacted cache, full layers a full cache
    for l in 0..m.model.n_layers {
        let cl = m.cache_len(l).unwrap();
        if m.model.is_routed_block(l) {
            assert!(cl < m.max_decode_len, "layer {l} cache {cl}");
        } else {
            assert_eq!(cl, m.max_decode_len);
        }
    }
    // init checkpoint matches the ABI exactly
    let params = bundle.init_params().expect("init params load");
    assert_eq!(params.len(), m.params.len());
    for (t, spec) in params.iter().zip(&m.params) {
        assert_eq!(t.shape(), spec.shape.as_slice(), "{}", spec.name);
    }
}

#[test]
fn train_step_runs_and_is_deterministic() {
    let Some(bundle) = open("mod_tiny") else { return };
    let run = |steps: u64| -> Vec<f32> {
        let mut trainer =
            Trainer::new(bundle.clone(), data_for(&bundle, 7), None).unwrap();
        let mut last = Vec::new();
        for s in 0..steps {
            let batch = data_for(&bundle, 7).batch_at(s);
            last = trainer.train_one(&batch).unwrap();
        }
        last
    };
    let a = run(2);
    let b = run(2);
    assert!(a.iter().all(|v| v.is_finite()), "{a:?}");
    assert_eq!(a, b, "same seed + same steps must reproduce exactly");
}

#[test]
fn training_reduces_loss() {
    let Some(bundle) = open("mod_tiny") else { return };
    let mut trainer =
        Trainer::new(bundle.clone(), data_for(&bundle, 7), None).unwrap();
    let mut first_ce = f32::NAN;
    let mut last_ce = f32::NAN;
    for s in 0..12 {
        let batch = data_for(&bundle, 7).batch_at(s);
        let m = trainer.train_one(&batch).unwrap();
        if s == 0 {
            first_ce = m[1];
        }
        last_ce = m[1];
    }
    assert!(
        last_ce < first_ce,
        "ce did not improve: {first_ce} -> {last_ce}"
    );
}

#[test]
fn checkpoint_resume_is_bit_exact() {
    let Some(bundle) = open("mod_tiny") else { return };
    let dir = std::env::temp_dir().join("mod_resume_test");
    let _ = std::fs::remove_dir_all(&dir);

    // run 4 steps straight through
    let mut t1 =
        Trainer::new(bundle.clone(), data_for(&bundle, 7), None).unwrap();
    let mut straight = Vec::new();
    for s in 0..4 {
        straight = t1.train_one(&data_for(&bundle, 7).batch_at(s)).unwrap();
    }

    // run 2 steps, checkpoint, resume, run 2 more
    let mut t2 =
        Trainer::new(bundle.clone(), data_for(&bundle, 7), None).unwrap();
    for s in 0..2 {
        t2.train_one(&data_for(&bundle, 7).batch_at(s)).unwrap();
    }
    let ckpt = dir.join("mid.ckpt");
    t2.save_checkpoint(&ckpt).unwrap();
    let mut t3 =
        Trainer::new(bundle.clone(), data_for(&bundle, 7), Some(&ckpt))
            .unwrap();
    assert_eq!(t3.step(), 2);
    let mut resumed = Vec::new();
    for s in 2..4 {
        resumed = t3.train_one(&data_for(&bundle, 7).batch_at(s)).unwrap();
    }
    assert_eq!(straight, resumed, "resume must be bit-exact");
}

#[test]
fn eval_modes_all_run() {
    let Some(bundle) = open("mod_tiny") else { return };
    let trainer =
        Trainer::new(bundle.clone(), data_for(&bundle, 7), None).unwrap();
    for mode in ["topk", "router", "predictor"] {
        let e = trainer.evaluate(mode, 1).expect(mode);
        assert!(e.ce.is_finite() && e.ce > 0.0, "{mode}: {e:?}");
        assert!((0.0..=1.0).contains(&e.participation), "{mode}: {e:?}");
    }
    // top-k participation is exactly the capacity fraction
    let e = trainer.evaluate("topk", 1).unwrap();
    let expect = bundle.manifest.model.capacity(bundle.manifest.model.seq_len)
        as f64
        / bundle.manifest.model.seq_len as f64;
    assert!((e.participation - expect).abs() < 1e-5, "{e:?}");
}

#[test]
fn decode_skips_blocks_and_tracks_caches() {
    let Some(bundle) = open("mod_tiny") else { return };
    let params = bundle.init_params().unwrap();
    let mut session = DecodeSession::new(
        &bundle, &params, 1, RoutingDecision::RouterThreshold,
    )
    .unwrap();
    let mut tok = BOS as i32;
    for _ in 0..32 {
        let logits = session.step(&[tok], &[true]).unwrap();
        assert!(logits.iter().all(|v| v.is_finite()));
        tok = logits
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1))
            .unwrap()
            .0 as i32;
    }
    let rep = session.report();
    assert_eq!(rep.steps, 32);
    // full blocks always invoked; routed blocks sometimes skipped
    assert!(rep.blocks_invoked >= 2 * 32, "{rep:?}");
    // cache occupancy: full layers hold exactly one slot per step
    for cs in &rep.cache_stats {
        if !cs.routed {
            assert!((cs.occupancy - 32.0 / 256.0).abs() < 1e-9, "{cs:?}");
        } else {
            // routed layers hold at most as many as steps
            assert!(cs.occupancy <= 32.0 / cs.cache_len as f64 + 1e-9);
        }
    }
    // compacted caches save memory vs vanilla
    let (alloc, vanilla, ratio) =
        mod_transformer::serve::kv_cache::memory_savings(&rep.cache_stats);
    assert!(alloc < vanilla, "ratio {ratio}");
}

#[test]
fn decode_always_on_never_skips() {
    let Some(bundle) = open("mod_tiny") else { return };
    let params = bundle.init_params().unwrap();
    let mut session =
        DecodeSession::new(&bundle, &params, 1, RoutingDecision::AlwaysOn)
            .unwrap();
    let mut tok = BOS as i32;
    for _ in 0..8 {
        session.step(&[tok], &[true]).unwrap();
        tok = 1;
    }
    let rep = session.report();
    assert_eq!(rep.blocks_skipped, 0);
    assert_eq!(rep.blocks_invoked, 4 * 8);
}

#[test]
fn decode_capacity_drops_when_cache_full() {
    let Some(bundle) = open("mod_tiny") else { return };
    let params = bundle.init_params().unwrap();
    // AlwaysOn routes every token through every block; the routed layers'
    // caches (48 slots) overflow after 48 steps -> drops (paper 3.1).
    let mut session =
        DecodeSession::new(&bundle, &params, 1, RoutingDecision::AlwaysOn)
            .unwrap();
    let mut tok = BOS as i32;
    for _ in 0..60 {
        session.step(&[tok], &[true]).unwrap();
        tok = 2;
    }
    let rep = session.report();
    assert!(rep.capacity_drops > 0, "{rep:?}");
    for cs in &rep.cache_stats {
        if cs.routed {
            assert!((cs.occupancy - 1.0).abs() < 1e-9, "routed cache full");
        }
    }
}

#[test]
fn batched_generation_matches_request_count() {
    let Some(bundle) = open("mod_tiny") else { return };
    let params = bundle.init_params().unwrap();
    let reqs: Vec<Request> = (0..3)
        .map(|i| Request {
            prompt: vec![BOS, 5, 10],
            max_new: 6,
            temperature: 0.0,
            top_k: 0,
            seed: i,
        })
        .collect();
    let refs: Vec<&Request> = reqs.iter().collect();
    let (outs, report) =
        generate_batch(&bundle, &params, 4, RoutingDecision::RouterThreshold,
                       &refs)
            .unwrap();
    assert_eq!(outs.len(), 3);
    for o in &outs {
        assert!(!o.is_empty() && o.len() <= 6);
    }
    assert!(report.tokens_generated > 0);
}

#[test]
fn greedy_batch_rows_match_single_row_decode() {
    // batching must not change a row's output (greedy, same prompt)
    let Some(bundle) = open("mod_tiny") else { return };
    let params = bundle.init_params().unwrap();
    let req = Request {
        prompt: vec![BOS, 5, 10, 20],
        max_new: 8,
        temperature: 0.0,
        top_k: 0,
        seed: 0,
    };
    let (single, _) = generate_batch(
        &bundle, &params, 1, RoutingDecision::RouterThreshold, &[&req],
    )
    .unwrap();
    let reqs = [req.clone(), req.clone(), req.clone(), req];
    let refs: Vec<&Request> = reqs.iter().collect();
    let (batched, _) = generate_batch(
        &bundle, &params, 4, RoutingDecision::RouterThreshold, &refs,
    )
    .unwrap();
    for row in &batched {
        assert_eq!(row, &single[0], "batching changed greedy output");
    }
}

#[test]
fn server_round_trip() {
    let Some(bundle) = open("mod_tiny") else { return };
    let params = Arc::new(bundle.init_params().unwrap());
    let server = Server::spawn(
        bundle.clone(),
        params,
        ServeConfig { batch_wait_ms: 1, ..Default::default() },
        RoutingDecision::RouterThreshold,
    );
    let pendings: Vec<_> = (0..3)
        .map(|i| {
            server
                .submit(Request {
                    prompt: vec![BOS, 3],
                    max_new: 4,
                    temperature: 0.0,
                    top_k: 0,
                    seed: i,
                })
                .unwrap()
        })
        .collect();
    for p in pendings {
        let resp = p.wait().expect("response");
        assert!(!resp.tokens.is_empty());
    }
    let stats = server.stats();
    assert_eq!(stats.requests, 3);
    server.shutdown();
}

#[test]
fn trainer_rejects_mismatched_data_shape() {
    let Some(bundle) = open("mod_tiny") else { return };
    let bad = BatchIter::new(
        MarkovCorpus::new(CorpusSpec::default(), 7),
        2, // wrong batch size
        bundle.manifest.model.seq_len,
    );
    assert!(Trainer::new(bundle.clone(), bad, None).is_err());
}

#[test]
fn checkpoint_format_interops_with_python_abi() {
    // MODCKPT written by rust parses the same fields python wrote in
    // init.ckpt — verified by reloading the init checkpoint and re-saving.
    let Some(bundle) = open("mod_tiny") else { return };
    let params = bundle.init_params().unwrap();
    let named = bundle.named_params(&params);
    let dir = std::env::temp_dir().join("mod_ckpt_interop");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("resaved.ckpt");
    checkpoint::save(&path, &named).unwrap();
    let back = checkpoint::load(&path).unwrap();
    let reordered = bundle.order_params(back).unwrap();
    assert_eq!(reordered, params);
}

#[test]
fn full_run_writes_metrics_and_checkpoint() {
    let Some(bundle) = open("mod_tiny") else { return };
    let dir = std::env::temp_dir().join("mod_full_run_test");
    let _ = std::fs::remove_dir_all(&dir);
    let mut trainer =
        Trainer::new(bundle.clone(), data_for(&bundle, 7), None).unwrap();
    let outcome = trainer
        .run(&TrainerOptions {
            steps: Some(3),
            log_every: 1,
            ckpt_every: 0,
            run_dir: dir.clone(),
            resume: None,
        })
        .unwrap();
    assert!(outcome.metrics_path.exists());
    assert!(outcome.ckpt_path.exists());
    let rows =
        mod_transformer::coordinator::metrics::load_jsonl(&outcome.metrics_path)
            .unwrap();
    assert_eq!(rows.len(), 3);
    assert!(dir.join("metrics.csv").exists());
}
