//! Property-based tests on coordinator invariants (in-repo `util::prop`
//! harness; proptest is unavailable offline). Each property runs hundreds
//! of randomized cases from a fixed seed.

use mod_transformer::config::{
    FfMode, ModelConfig, RoutingMode, TrainConfig,
};
use mod_transformer::data::bpe::Bpe;
use mod_transformer::data::rng::Pcg32;
use mod_transformer::data::tokenizer::{ByteTokenizer, Tokenizer};
use mod_transformer::data::{BatchIter, CorpusSpec, MarkovCorpus};
use mod_transformer::flops;
use mod_transformer::runtime::native::{
    forward, init_params, train, ParamTable, RouteMode,
};
use mod_transformer::runtime::{Bundle, SyntheticSpec};
use mod_transformer::serve::{
    sample, sample_sort_oracle, DecodeSession, LayerKvCache, RoutingDecision,
};
use mod_transformer::util::json::Json;
use mod_transformer::util::pool;
use mod_transformer::util::prop::{forall, normal_vec, usize_in};

fn random_model(rng: &mut Pcg32) -> ModelConfig {
    let n_heads = usize_in(rng, 1, 4);
    let d_head = [8, 16, 32][usize_in(rng, 0, 2)];
    let routing = [
        RoutingMode::None,
        RoutingMode::ModEvery,
        RoutingMode::ModInterleaved,
        RoutingMode::Stochastic,
    ][usize_in(rng, 0, 3)];
    let ff_mode = [FfMode::Dense, FfMode::Moe, FfMode::ModeIntegrated]
        [usize_in(rng, 0, 2)];
    ModelConfig {
        vocab_size: usize_in(rng, 16, 512),
        d_model: n_heads * d_head,
        n_layers: usize_in(rng, 1, 10),
        n_heads,
        d_head,
        d_ff: usize_in(rng, 8, 256),
        seq_len: usize_in(rng, 8, 512),
        routing,
        capacity_frac: 0.05 + 0.95 * (usize_in(rng, 0, 100) as f64 / 100.0),
        ff_mode,
        n_experts: usize_in(rng, 1, 6),
        ..Default::default()
    }
}

#[test]
fn prop_capacity_bounds() {
    // 1 <= capacity <= seq_len, monotone in capacity_frac
    forall(11, 300, |rng| random_model(rng), |cfg| {
        let c = cfg.capacity(cfg.seq_len);
        if c < 1 || c > cfg.seq_len {
            return Err(format!("capacity {c} out of [1,{}]", cfg.seq_len));
        }
        Ok(())
    });
}

#[test]
fn prop_routed_flops_never_exceed_vanilla_plus_router() {
    // MoD cost <= vanilla cost + router/predictor overhead, and strictly
    // less when capacity < 1 on some routed block.
    forall(12, 200, |rng| random_model(rng), |cfg| {
        let mut vanilla = cfg.clone();
        vanilla.routing = RoutingMode::None;
        let m = flops::model_flops(cfg).total();
        let v = flops::model_flops(&vanilla).total();
        let router_overhead: f64 = cfg
            .routed_layers()
            .iter()
            .map(|_| {
                2.0 * cfg.seq_len as f64
                    * cfg.d_model as f64
                    * (1.0 + cfg.predictor_hidden as f64)
            })
            .sum();
        if m > v + router_overhead + 1.0 {
            return Err(format!("MoD flops {m} > vanilla {v} + router"));
        }
        if cfg.capacity_frac < 0.5 && !cfg.routed_layers().is_empty() && m >= v
        {
            return Err(format!(
                "low capacity should save flops: {m} vs {v} ({cfg:?})"
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_flops_monotone_in_capacity() {
    forall(13, 200, |rng| {
        let mut cfg = random_model(rng);
        cfg.routing = RoutingMode::ModEvery;
        let lo = 0.05 + 0.4 * (usize_in(rng, 0, 100) as f64 / 100.0);
        let hi = (lo + 0.1 + 0.4 * (usize_in(rng, 0, 100) as f64 / 100.0)).min(1.0);
        (cfg, lo, hi)
    }, |(cfg, lo, hi)| {
        let mut a = cfg.clone();
        a.capacity_frac = *lo;
        let mut b = cfg.clone();
        b.capacity_frac = *hi;
        // rounding can equalize at tiny seq_len; allow equality
        if flops::model_flops(&a).total() > flops::model_flops(&b).total() + 1.0 {
            return Err("flops not monotone in capacity".into());
        }
        Ok(())
    });
}

#[test]
fn prop_kv_cache_never_over_allocates() {
    forall(14, 300, |rng| {
        let cache_len = usize_in(rng, 1, 64);
        let batch = usize_in(rng, 1, 8);
        let ops: Vec<(usize, bool)> = (0..usize_in(rng, 0, 200))
            .map(|_| (usize_in(rng, 0, batch - 1), rng.next_f64() < 0.1))
            .collect();
        (cache_len, batch, ops)
    }, |(cache_len, batch, ops)| {
        let mut cache = LayerKvCache::new(0, *cache_len, *batch, true);
        let mut used = vec![0usize; *batch];
        for &(row, reset) in ops {
            if reset {
                cache.release_row(row);
                cache.admit_row(row);
                used[row] = 0;
            } else {
                match cache.try_alloc(row) {
                    Some(slot) => {
                        if slot != used[row] {
                            return Err(format!(
                                "slot {slot} != expected {}", used[row]
                            ));
                        }
                        used[row] += 1;
                        if used[row] > *cache_len {
                            return Err("over-allocated".into());
                        }
                    }
                    None => {
                        if used[row] != *cache_len {
                            return Err(format!(
                                "dropped early at {}/{}", used[row], cache_len
                            ));
                        }
                    }
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_sampling_in_topk_support() {
    forall(15, 300, |rng| {
        let n = usize_in(rng, 2, 300);
        let logits = normal_vec(rng, n);
        let k = usize_in(rng, 1, n);
        let seed = rng.next_u32() as u64;
        (logits, k, seed)
    }, |(logits, k, seed)| {
        let mut rng = Pcg32::new(*seed, 0);
        let idx = sample(logits, 0.7, *k, &mut rng);
        if idx >= logits.len() {
            return Err("index out of range".into());
        }
        // idx must be among the k largest
        let mut sorted: Vec<f32> = logits.clone();
        sorted.sort_by(|a, b| b.total_cmp(a));
        let threshold = sorted[*k - 1];
        if logits[idx] < threshold - 1e-6 {
            return Err(format!(
                "sampled {idx} (logit {}) below top-{k} threshold {threshold}",
                logits[idx]
            ));
        }
        Ok(())
    });
}

/// The partial-selection (`select_nth_unstable_by`) top-k fast path must
/// emit the exact token stream of the old full-sort path for fixed seeds
/// — across vocab sizes, k values, temperatures, and repeated logit
/// values (ties at the top-k boundary).
#[test]
fn prop_topk_selection_matches_sort_oracle() {
    forall(17, 400, |rng| {
        let n = usize_in(rng, 2, 400);
        let mut logits = normal_vec(rng, n);
        // inject ties: duplicate a few values so the boundary is contested
        for _ in 0..usize_in(rng, 0, 8) {
            let src = usize_in(rng, 0, n - 1);
            let dst = usize_in(rng, 0, n - 1);
            logits[dst] = logits[src];
        }
        let k = usize_in(rng, 1, n + 2); // occasionally k >= n (no cutoff)
        let temp = 0.1 + 2.0 * (usize_in(rng, 0, 100) as f64 / 100.0);
        let seed = rng.next_u32() as u64;
        let draws = usize_in(rng, 1, 8);
        (logits, k, temp, seed, draws)
    }, |(logits, k, temp, seed, draws)| {
        let mut fast_rng = Pcg32::new(*seed, 0);
        let mut slow_rng = Pcg32::new(*seed, 0);
        for d in 0..*draws {
            let fast = sample(logits, *temp, *k, &mut fast_rng);
            let slow = sample_sort_oracle(logits, *temp, *k, &mut slow_rng);
            if fast != slow {
                return Err(format!(
                    "draw {d}: fast path {fast} != sort oracle {slow} \
                     (n={}, k={k}, temp={temp})",
                    logits.len()
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_greedy_sampling_is_argmax() {
    forall(16, 200, |rng| {
        let n = usize_in(rng, 1, 100);
        normal_vec(rng, n)
    }, |logits| {
        let mut rng = Pcg32::new(0, 0);
        let idx = sample(logits, 0.0, 0, &mut rng);
        let max = logits.iter().cloned().fold(f32::MIN, f32::max);
        if (logits[idx] - max).abs() > 1e-9 {
            return Err("greedy != argmax".into());
        }
        Ok(())
    });
}

#[test]
fn prop_json_roundtrip() {
    fn random_json(rng: &mut Pcg32, depth: usize) -> Json {
        match if depth == 0 { usize_in(rng, 0, 3) } else { usize_in(rng, 0, 5) } {
            0 => Json::Null,
            1 => Json::Bool(rng.next_f64() < 0.5),
            2 => Json::Num((rng.next_normal() * 100.0 * 64.0).round() / 64.0),
            3 => Json::Str(
                (0..usize_in(rng, 0, 12))
                    .map(|_| {
                        ['a', 'Z', '"', '\\', '\n', 'é', '∆', ' ']
                            [usize_in(rng, 0, 7)]
                    })
                    .collect(),
            ),
            4 => Json::Arr(
                (0..usize_in(rng, 0, 4))
                    .map(|_| random_json(rng, depth - 1))
                    .collect(),
            ),
            _ => Json::Obj(
                (0..usize_in(rng, 0, 4))
                    .map(|i| (format!("k{i}"), random_json(rng, depth - 1)))
                    .collect(),
            ),
        }
    }
    forall(17, 300, |rng| random_json(rng, 3), |doc| {
        let text = doc.to_string();
        let back = Json::parse(&text).map_err(|e| format!("parse: {e}"))?;
        if &back != doc {
            return Err(format!("roundtrip mismatch: {text}"));
        }
        let pretty = Json::parse(&doc.to_string_pretty())
            .map_err(|e| format!("pretty parse: {e}"))?;
        if &pretty != doc {
            return Err("pretty roundtrip mismatch".into());
        }
        Ok(())
    });
}

#[test]
fn prop_corpus_batches_deterministic_and_in_vocab() {
    forall(18, 60, |rng| {
        (rng.next_u32() as u64, usize_in(rng, 1, 8), usize_in(rng, 2, 128),
         rng.next_u32() as u64 % 50)
    }, |(seed, batch, seq, step)| {
        let mk = || {
            BatchIter::new(
                MarkovCorpus::new(CorpusSpec::default(), *seed), *batch, *seq,
            )
        };
        let a = mk().batch_at(*step);
        let b = mk().batch_at(*step);
        if a != b {
            return Err("batches not deterministic".into());
        }
        if a.len() != batch * seq {
            return Err("wrong batch shape".into());
        }
        if a.iter().any(|&t| t < 0 || t >= 259) {
            return Err("token out of vocab".into());
        }
        Ok(())
    });
}

#[test]
fn prop_bpe_roundtrip_arbitrary_ascii() {
    forall(19, 100, |rng| {
        let train: String = (0..usize_in(rng, 10, 300))
            .map(|_| (b'a' + usize_in(rng, 0, 5) as u8) as char)
            .collect();
        let text: String = (0..usize_in(rng, 0, 100))
            .map(|_| (b'a' + usize_in(rng, 0, 7) as u8) as char)
            .collect();
        let merges = usize_in(rng, 0, 40);
        (train, text, merges)
    }, |(train, text, merges)| {
        let bpe = Bpe::train(train, *merges);
        if bpe.decode(&bpe.encode(text)) != *text {
            return Err(format!("roundtrip failed for {text:?}"));
        }
        Ok(())
    });
}

#[test]
fn prop_byte_tokenizer_roundtrip() {
    forall(20, 200, |rng| {
        (0..usize_in(rng, 0, 64))
            .map(|_| ['a', '0', ' ', 'é', '∆', '😀'][usize_in(rng, 0, 5)])
            .collect::<String>()
    }, |text| {
        let t = ByteTokenizer;
        if t.decode(&t.encode(text)) != *text {
            return Err(format!("roundtrip failed for {text:?}"));
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// Thread-count parity: the worker pool must be invisible in the numbers.
// ---------------------------------------------------------------------------

fn parity_model(ff_mode: FfMode, routing: RoutingMode) -> ModelConfig {
    ModelConfig {
        vocab_size: 61,
        d_model: 32,
        n_layers: 2,
        n_heads: 2,
        d_head: 16,
        d_ff: 64,
        seq_len: 32,
        routing,
        capacity_frac: 0.5,
        train_predictor: routing != RoutingMode::None,
        predictor_hidden: 8,
        ff_mode,
        n_experts: 2,
        expert_capacity_frac: 0.5,
        ..Default::default()
    }
}

/// Everything the parity claim covers, as raw bit patterns: teacher-forced
/// logits, full train-step gradients, and batched layer-sliced decode
/// logits.
struct StackBits {
    logits: Vec<u32>,
    grads: Vec<Vec<u32>>,
    decode: Vec<u32>,
}

fn f32_bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

fn run_stack(cfg: &ModelConfig) -> StackBits {
    let named = init_params(cfg, 11);
    let names: Vec<String> = named.iter().map(|(n, _)| n.clone()).collect();
    let data: Vec<&[f32]> =
        named.iter().map(|(_, t)| t.as_f32().unwrap()).collect();
    let table = ParamTable::from_named(&names, data).unwrap();
    let (b, s) = (3usize, cfg.seq_len);
    let tokens: Vec<i32> = (0..b * s)
        .map(|r| ((r * 7 + 3) % cfg.vocab_size) as i32)
        .collect();
    let fwd =
        forward::forward(cfg, &table, &tokens, b, s, RouteMode::Topk, 0)
            .unwrap();
    let lg = train::loss_and_grads(cfg, &table, &tokens, b, s, 0).unwrap();

    // batched decode through the layer-sliced executables (2 rows so the
    // per-row block-decode tasks actually fan out)
    let bundle = Bundle::native(
        "thread_parity",
        cfg,
        &TrainConfig::default(),
        &SyntheticSpec {
            seed: 11,
            decode_batches: vec![2],
            max_decode_len: s,
            ..Default::default()
        },
    )
    .unwrap();
    let params = bundle.init_params().unwrap();
    let mut session =
        DecodeSession::new(&bundle, &params, 2, RoutingDecision::RouterThreshold)
            .unwrap();
    let mut decode = Vec::new();
    let mut toks = vec![1i32, 2];
    for step in 0..16usize {
        let logits = session.step(&toks, &[true, true]).unwrap();
        decode.extend(f32_bits(&logits));
        toks = vec![
            ((step * 5 + 3) % cfg.vocab_size) as i32,
            ((step * 3 + 1) % cfg.vocab_size) as i32,
        ];
    }

    StackBits {
        logits: f32_bits(&fwd.logits),
        grads: lg.grads.iter().map(|g| f32_bits(g)).collect(),
        decode,
    }
}

/// The tentpole invariant: forward logits, train-step gradients and
/// decode outputs are **bitwise identical** across `RP_THREADS ∈
/// {1, 2, 4, 7}` for dense, MoE and integrated-MoDE variants. Width 7 is
/// deliberately odd so row bands and batch chunks split unevenly; the
/// min-work gate is disabled inside `with_threads` so every parallel
/// region really runs parallel.
#[test]
fn prop_threaded_stack_bitwise_equal_across_thread_counts() {
    let _g = pool::knob_guard();
    let cases: &[(FfMode, RoutingMode)] = &[
        (FfMode::Dense, RoutingMode::None),
        (FfMode::Dense, RoutingMode::ModInterleaved),
        (FfMode::Moe, RoutingMode::ModInterleaved), // staged MoDE
        (FfMode::ModeIntegrated, RoutingMode::None),
    ];
    for &(ff_mode, routing) in cases {
        let cfg = parity_model(ff_mode, routing);
        let reference = pool::with_threads(1, || run_stack(&cfg));
        for &nt in &[2usize, 4, 7] {
            let got = pool::with_threads(nt, || run_stack(&cfg));
            assert_eq!(
                got.logits, reference.logits,
                "{ff_mode:?}/{routing:?}: forward logits diverged at {nt} \
                 threads"
            );
            assert_eq!(got.grads.len(), reference.grads.len());
            for (i, (a, b)) in
                got.grads.iter().zip(&reference.grads).enumerate()
            {
                assert_eq!(
                    a, b,
                    "{ff_mode:?}/{routing:?}: grad tensor {i} diverged at \
                     {nt} threads"
                );
            }
            assert_eq!(
                got.decode, reference.decode,
                "{ff_mode:?}/{routing:?}: decode logits diverged at {nt} \
                 threads"
            );
        }
    }
}

#[test]
fn prop_n_params_positive_and_monotone_in_depth() {
    forall(21, 200, |rng| random_model(rng), |cfg| {
        let n = cfg.n_params();
        if n == 0 {
            return Err("zero params".into());
        }
        let mut deeper = cfg.clone();
        deeper.n_layers += 1;
        if deeper.n_params() <= n {
            return Err("adding a layer must add params".into());
        }
        Ok(())
    });
}
