//! `repro lint` end-to-end: every rule fires on its fixture at the
//! exact line, every allowlisted negative stays silent, and — the self
//! check — the real tree is clean (the lint CI lane is the same
//! assertion run as a binary).

use mod_transformer::lint::{self, metrics_doc, rules, scan, Finding};

/// The fixture must yield exactly one finding: `rule` at `line`. The
/// allowlisted twin in the same file proves suppression works per-site.
fn assert_single(rel: &str, text: &str, rule: &str, line: usize) {
    let fs = lint::lint_source(rel, text);
    let got: Vec<(&str, usize)> =
        fs.iter().map(|f| (f.rule, f.line)).collect();
    assert_eq!(got, vec![(rule, line)], "findings for {rel}: {:?}", dump(&fs));
}

fn dump(fs: &[Finding]) -> Vec<String> {
    fs.iter()
        .map(|f| format!("{}:{}:{} [{}] {}", f.file, f.line, f.col, f.rule, f.message))
        .collect()
}

#[test]
fn d1_hash_iteration_in_serve() {
    assert_single(
        "serve/fixture.rs",
        include_str!("lint_fixtures/d1.rs"),
        "D1",
        7,
    );
}

#[test]
fn d1_silent_outside_scoped_dirs() {
    // same source under analysis/: hash iteration is fine there
    let fs = lint::lint_source(
        "analysis/fixture.rs",
        include_str!("lint_fixtures/d1.rs"),
    );
    assert!(fs.is_empty(), "{:?}", dump(&fs));
}

#[test]
fn d2_wallclock_in_kernels() {
    assert_single(
        "runtime/native/fixture.rs",
        include_str!("lint_fixtures/d2.rs"),
        "D2",
        6,
    );
}

#[test]
fn d3_cross_closure_accumulation() {
    assert_single(
        "runtime/native/kernels.rs",
        include_str!("lint_fixtures/d3.rs"),
        "D3",
        8,
    );
}

#[test]
fn p1_unwrap_on_request_path() {
    assert_single(
        "serve/engine.rs",
        include_str!("lint_fixtures/p1.rs"),
        "P1",
        6,
    );
}

#[test]
fn l1_lock_order_inversion() {
    assert_single(
        "serve/l1_fixture.rs",
        include_str!("lint_fixtures/l1.rs"),
        "L1",
        13,
    );
}

#[test]
fn a1_relaxed_ordering() {
    assert_single(
        "serve/a1_fixture.rs",
        include_str!("lint_fixtures/a1.rs"),
        "A1",
        6,
    );
}

#[test]
fn m1_source_and_doc_drift_both_directions() {
    let text = include_str!("lint_fixtures/m1_source.rs");
    let lines = scan::scan(text);
    let flat = rules::Flat::new(&lines);
    let regs = metrics_doc::registrations("m1_source.rs", &lines, &flat);
    assert_eq!(
        regs.iter()
            .map(|r| (r.name.as_str(), r.line))
            .collect::<Vec<_>>(),
        vec![("engine_demo_total", 7), ("engine_other_total", 11)]
    );
    let readme = include_str!("lint_fixtures/m1_readme.md");
    let fs = metrics_doc::cross_check(&regs, "fixture_readme.md", readme);
    let got: Vec<(&str, &str, usize)> = fs
        .iter()
        .map(|f| (f.rule, f.file.as_str(), f.line))
        .collect();
    assert!(
        got.contains(&("M1", "m1_source.rs", 11)),
        "missing-from-doc finding: {:?}",
        dump(&fs)
    );
    assert!(
        got.contains(&("M1", "fixture_readme.md", 6)),
        "ghost-doc-entry finding: {:?}",
        dump(&fs)
    );
    assert_eq!(got.len(), 2, "{:?}", dump(&fs));
}

/// M1 covers the `mod_layer_` routing-ledger prefix: `_with`-style
/// registrations are picked up by name, README tokens with a trailing
/// `{layer,path}` label list parse to the bare metric name, and drift
/// fires in both directions — an undocumented registration and a ghost
/// doc entry.
#[test]
fn m1_covers_mod_layer_prefix() {
    let text = include_str!("lint_fixtures/m1_mod_source.rs");
    let lines = scan::scan(text);
    let flat = rules::Flat::new(&lines);
    let regs = metrics_doc::registrations("m1_mod_source.rs", &lines, &flat);
    let mut by_line: Vec<(&str, usize)> =
        regs.iter().map(|r| (r.name.as_str(), r.line)).collect();
    by_line.sort_by_key(|(_, l)| *l);
    assert_eq!(
        by_line,
        vec![
            ("mod_layer_tokens_total", 7),
            ("mod_layer_selection_rate", 12),
            ("mod_layer_orphan_total", 17),
        ]
    );
    let readme = include_str!("lint_fixtures/m1_mod_readme.md");
    // the label lists end the token: both documented names parse bare
    let parsed = metrics_doc::readme_names(readme);
    let doc_names: Vec<&str> =
        parsed.iter().map(|(n, _, _)| n.as_str()).collect();
    assert!(doc_names.contains(&"mod_layer_tokens_total"), "{doc_names:?}");
    assert!(
        doc_names.contains(&"mod_layer_selection_rate"),
        "{doc_names:?}"
    );
    let fs = metrics_doc::cross_check(&regs, "fixture_readme.md", readme);
    let got: Vec<(&str, &str, usize)> = fs
        .iter()
        .map(|f| (f.rule, f.file.as_str(), f.line))
        .collect();
    assert!(
        got.contains(&("M1", "m1_mod_source.rs", 17)),
        "undocumented mod_layer registration: {:?}",
        dump(&fs)
    );
    assert!(
        got.contains(&("M1", "fixture_readme.md", 7)),
        "ghost mod_layer doc entry: {:?}",
        dump(&fs)
    );
    assert_eq!(got.len(), 2, "{:?}", dump(&fs));
}

/// The rendered report carries file:line:col, the rule ID, and a GitHub
/// annotation when asked for one.
#[test]
fn report_renders_spans_and_annotations() {
    let fs = lint::lint_source(
        "serve/engine.rs",
        include_str!("lint_fixtures/p1.rs"),
    );
    let plain = lint::report::render(&fs, false);
    assert!(plain.contains("serve/engine.rs:6:"), "{plain}");
    assert!(plain.contains("[P1]"), "{plain}");
    assert!(plain.contains("1 finding"), "{plain}");
    let gh = lint::report::render(&fs, true);
    assert!(gh.contains("::error file=serve/engine.rs,line=6"), "{gh}");
    let clean = lint::report::render(&[], false);
    assert!(clean.contains("clean"), "{clean}");
}

/// The self-check: the tree this test compiled from passes its own lint.
/// This is the same assertion CI's `lint` lane makes via the binary.
#[test]
fn real_tree_is_clean() {
    let root = lint::find_root(std::path::Path::new(env!("CARGO_MANIFEST_DIR")))
        .expect("repo root above CARGO_MANIFEST_DIR");
    let fs = lint::lint_tree(&root).expect("lint_tree");
    assert!(fs.is_empty(), "lint findings on the real tree:\n{:#?}", dump(&fs));
}
