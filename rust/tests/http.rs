//! Integration tests for the HTTP/SSE gateway + `/metrics` registry.
//!
//! Everything runs against a synthetic in-memory bundle and a loopback
//! `TcpListener` — raw `TcpStream` clients, no HTTP client library.
//!
//! The metrics registry is process-global, so every test that drives an
//! `Engine` holds `pool::knob_guard()` for its full body: engine counter
//! *deltas* measured around one test's traffic are then exact, and the
//! thread-width premises of the determinism test can't race either.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::Arc;
use std::time::Duration;

use mod_transformer::config::{ModelConfig, RoutingMode, ServeConfig, TrainConfig};
use mod_transformer::data::BOS;
use mod_transformer::runtime::{Bundle, SyntheticSpec};
use mod_transformer::serve::http::parser::Limits;
use mod_transformer::serve::{
    generate_batch, Engine, GenerateParams, HttpConfig, HttpServer,
    RoutingDecision,
};
use mod_transformer::util::json::Json;
use mod_transformer::util::metrics::sample_value;
use mod_transformer::util::pool;

const SEQ: usize = 32;
const MAX_DECODE: usize = 64;
const DECISION: RoutingDecision = RoutingDecision::RouterThreshold;

fn open(name: &str) -> Arc<Bundle> {
    let model = ModelConfig {
        vocab_size: 259,
        d_model: 32,
        n_layers: 4,
        n_heads: 2,
        d_head: 16,
        d_ff: 64,
        seq_len: SEQ,
        routing: RoutingMode::ModInterleaved,
        capacity_frac: 0.125,
        train_predictor: true,
        predictor_hidden: 16,
        ..Default::default()
    };
    let train = TrainConfig {
        batch_size: 4,
        warmup_steps: 5,
        total_steps: 200,
        ..Default::default()
    };
    Arc::new(
        Bundle::native(
            name,
            &model,
            &train,
            &SyntheticSpec {
                seed: 7,
                decode_batches: vec![1, 4],
                max_decode_len: MAX_DECODE,
                ..Default::default()
            },
        )
        .expect("synthetic bundle"),
    )
}

fn start_gateway(
    workers: usize,
    cfg: HttpConfig,
) -> (Arc<Engine>, HttpServer) {
    let bundle = open("mod_tiny_http");
    let params = Arc::new(bundle.init_params().unwrap());
    let engine = Arc::new(
        Engine::start(
            bundle,
            params,
            ServeConfig { workers, ..Default::default() },
            DECISION,
        )
        .unwrap(),
    );
    let server = HttpServer::start(engine.clone(), cfg).unwrap();
    (engine, server)
}

fn test_config() -> HttpConfig {
    HttpConfig { read_timeout: Duration::from_secs(5), ..Default::default() }
}

/// Write one raw request, half-close, read the full response stream.
/// A 30s client-side timeout turns a wedged server into a loud failure
/// instead of a hung test binary.
fn exchange(addr: SocketAddr, raw: &[u8]) -> Vec<u8> {
    let mut s = TcpStream::connect(addr).expect("connect");
    s.set_read_timeout(Some(Duration::from_secs(30)))
        .expect("set timeout");
    s.write_all(raw).expect("write request");
    s.shutdown(std::net::Shutdown::Write).expect("half-close");
    let mut buf = Vec::new();
    s.read_to_end(&mut buf).expect("read response");
    buf
}

/// Split one response into (head, body) at the header terminator.
fn split_response(buf: &[u8]) -> (String, Vec<u8>) {
    let pos = buf
        .windows(4)
        .position(|w| w == b"\r\n\r\n")
        .unwrap_or_else(|| panic!("no header terminator in {:?}",
                                  String::from_utf8_lossy(buf)));
    (
        String::from_utf8(buf[..pos].to_vec()).expect("UTF-8 head"),
        buf[pos + 4..].to_vec(),
    )
}

fn status_of(head: &str) -> u16 {
    head.split(' ')
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or_else(|| panic!("bad status line: {head:?}"))
}

fn header_of<'a>(head: &'a str, name: &str) -> Option<&'a str> {
    head.lines().skip(1).find_map(|l| {
        let (k, v) = l.split_once(':')?;
        if k.eq_ignore_ascii_case(name) {
            Some(v.trim())
        } else {
            None
        }
    })
}

/// Parse a sequence of responses (pipelining) using Content-Length.
fn parse_responses(mut buf: &[u8]) -> Vec<(u16, Vec<u8>)> {
    let mut out = Vec::new();
    while !buf.is_empty() {
        let pos = buf
            .windows(4)
            .position(|w| w == b"\r\n\r\n")
            .expect("header terminator");
        let head = String::from_utf8(buf[..pos].to_vec()).unwrap();
        let len: usize = header_of(&head, "content-length")
            .expect("content-length framed response")
            .parse()
            .unwrap();
        let body = buf[pos + 4..pos + 4 + len].to_vec();
        out.push((status_of(&head), body));
        buf = &buf[pos + 4 + len..];
    }
    out
}

fn get(addr: SocketAddr, path: &str) -> (u16, Vec<u8>) {
    let raw = format!("GET {path} HTTP/1.1\r\nConnection: close\r\n\r\n");
    let (head, body) = split_response(&exchange(addr, raw.as_bytes()));
    (status_of(&head), body)
}

fn post_json(addr: SocketAddr, path: &str, json: &str) -> (u16, Vec<u8>) {
    let raw = format!(
        "POST {path} HTTP/1.1\r\nContent-Length: {}\r\n\
         Connection: close\r\n\r\n{json}",
        json.len()
    );
    let (head, body) = split_response(&exchange(addr, raw.as_bytes()));
    (status_of(&head), body)
}

/// SSE frames of one streamed response body: (event, data) pairs.
fn parse_sse(body: &[u8]) -> Vec<(String, Json)> {
    let text = std::str::from_utf8(body).expect("SSE body is UTF-8");
    text.split("\n\n")
        .filter(|f| !f.trim().is_empty())
        .map(|f| {
            let mut event = None;
            let mut data = None;
            for line in f.lines() {
                if let Some(v) = line.strip_prefix("event: ") {
                    event = Some(v.to_string());
                } else if let Some(v) = line.strip_prefix("data: ") {
                    data = Some(Json::parse(v).expect("frame data is JSON"));
                }
            }
            (
                event.unwrap_or_else(|| panic!("frame without event: {f:?}")),
                data.unwrap_or_else(|| panic!("frame without data: {f:?}")),
            )
        })
        .collect()
}

/// Stream one generation over SSE; returns (tokens, terminal event name).
fn sse_generate(addr: SocketAddr, body_json: &str) -> (Vec<u16>, String) {
    let raw = format!(
        "POST /v1/generate?stream=1 HTTP/1.1\r\nContent-Length: {}\r\n\r\n{}",
        body_json.len(),
        body_json
    );
    let (head, body) = split_response(&exchange(addr, raw.as_bytes()));
    assert_eq!(status_of(&head), 200, "{head}");
    assert_eq!(
        header_of(&head, "content-type"),
        Some("text/event-stream"),
        "{head}"
    );
    let frames = parse_sse(&body);
    assert!(!frames.is_empty(), "empty SSE stream");
    let mut tokens = Vec::new();
    for (i, (event, data)) in frames.iter().enumerate() {
        match event.as_str() {
            "token" => {
                assert_eq!(
                    data.req_usize("index").unwrap(),
                    tokens.len(),
                    "token frames must arrive in order"
                );
                tokens.push(data.req_usize("token").unwrap() as u16);
            }
            "done" | "error" => {
                assert_eq!(i, frames.len() - 1, "terminal frame must be last");
            }
            other => panic!("unknown SSE event {other:?}"),
        }
    }
    let terminal = frames.last().unwrap().0.clone();
    assert!(
        terminal == "done" || terminal == "error",
        "stream must end with a terminal frame, got {terminal:?}"
    );
    (tokens, terminal)
}

// ---------------------------------------------------------------------

#[test]
fn healthz_generate_and_error_status_table() {
    let _g = pool::knob_guard();
    let (engine, server) = start_gateway(1, test_config());
    let addr = server.local_addr();

    let (status, body) = get(addr, "/healthz");
    assert_eq!(status, 200);
    assert_eq!(
        Json::parse(std::str::from_utf8(&body).unwrap())
            .unwrap()
            .req_str("status")
            .unwrap(),
        "ok"
    );

    // the endpoint/status table the README documents
    let table: Vec<(u16, (u16, Vec<u8>))> = vec![
        (404, get(addr, "/nope")),
        (405, post_json(addr, "/healthz", "{}")),
        (400, post_json(addr, "/v1/generate", "{not json")),
        (400, post_json(addr, "/v1/generate", "{\"max_new\":4}")), // no prompt
        (400, post_json(addr, "/v1/generate", "{\"prompt\":[70000]}")),
        (400, post_json(addr, "/v1/generate", "{\"prompt\":[1.5]}")),
        // engine-typed rejections surface as 400 too
        (
            400,
            post_json(addr, "/v1/generate", "{\"prompt\":[1],\"max_new\":0}"),
        ),
        (
            400,
            post_json(
                addr,
                "/v1/generate",
                "{\"prompt\":[1],\"max_new\":100000}",
            ),
        ),
    ];
    for (want, (got, body)) in table {
        assert_eq!(got, want, "{}", String::from_utf8_lossy(&body));
        if want != 200 {
            let j = Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
            assert!(j.get("error").is_some(), "error body is typed JSON");
        }
    }

    // a valid non-streaming generation
    let (status, body) = post_json(
        addr,
        "/v1/generate",
        "{\"prompt\":[256,3],\"max_new\":6,\"seed\":9}",
    );
    assert_eq!(status, 200, "{}", String::from_utf8_lossy(&body));
    let j = Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
    let tokens = j.get("tokens").unwrap().as_arr().unwrap();
    assert!(!tokens.is_empty() && tokens.len() <= 6);
    let usage = j.get("usage").unwrap();
    assert_eq!(usage.req_usize("prefill_tokens").unwrap(), 2);
    assert_eq!(usage.req_usize("decode_tokens").unwrap(), tokens.len());
    assert!(["eos", "stop", "max_tokens"]
        .contains(&usage.req_str("finish").unwrap().as_str()));

    server.shutdown();
    drop(engine);
}

#[test]
fn parser_limits_map_to_413_and_431_over_the_wire() {
    let _g = pool::knob_guard();
    let cfg = HttpConfig {
        limits: Limits {
            max_head_bytes: 256,
            max_headers: 4,
            max_body: 64,
        },
        ..test_config()
    };
    let (engine, server) = start_gateway(1, cfg);
    let addr = server.local_addr();

    let big_body = "x".repeat(65);
    let (status, _) = post_json(addr, "/v1/generate", &big_body);
    assert_eq!(status, 413);

    let raw = format!(
        "GET /healthz HTTP/1.1\r\nX-Pad: {}\r\n\r\n",
        "a".repeat(300)
    );
    let (head, _) = split_response(&exchange(addr, raw.as_bytes()));
    assert_eq!(status_of(&head), 431);

    let raw = "GET /healthz HTTP/1.1\r\nA: 1\r\nB: 2\r\nC: 3\r\nD: 4\r\nE: 5\r\n\r\n";
    let (head, _) = split_response(&exchange(addr, raw.as_bytes()));
    assert_eq!(status_of(&head), 431);

    server.shutdown();
    drop(engine);
}

#[test]
fn pipelined_requests_are_served_in_order_on_one_connection() {
    let _g = pool::knob_guard();
    let (engine, server) = start_gateway(1, test_config());
    let addr = server.local_addr();

    let body = "{\"prompt\":[256],\"max_new\":2,\"seed\":1}";
    let raw = format!(
        "POST /v1/generate HTTP/1.1\r\nContent-Length: {}\r\n\r\n{}\
         GET /healthz HTTP/1.1\r\nConnection: close\r\n\r\n",
        body.len(),
        body
    );
    let responses = parse_responses(&exchange(addr, raw.as_bytes()));
    assert_eq!(responses.len(), 2, "both pipelined requests answered");
    assert_eq!(responses[0].0, 200);
    let j =
        Json::parse(std::str::from_utf8(&responses[0].1).unwrap()).unwrap();
    assert!(j.get("tokens").is_some());
    assert_eq!(responses[1].0, 200);
    assert!(String::from_utf8_lossy(&responses[1].1).contains("ok"));

    server.shutdown();
    drop(engine);
}

/// Acceptance: N concurrent raw-TcpStream SSE clients receive token
/// sequences bitwise-identical to an in-process `generate_batch` run of
/// the same `GenerateParams`, at pool widths 1 and 4 (CI re-runs the
/// whole file under `RP_THREADS ∈ {1,4}` as well).
#[test]
fn concurrent_sse_streams_bitwise_match_engine() {
    let _g = pool::knob_guard();
    let bundle = open("mod_tiny_http");
    let params = bundle.init_params().unwrap();
    const N: usize = 4;
    let reqs: Vec<GenerateParams> = (0..N)
        .map(|i| {
            GenerateParams::new(vec![BOS, 5 + i as u16, 10])
                .max_new(8)
                .temperature(0.8)
                .top_k(8)
                .seed(100 + i as u64)
        })
        .collect();
    let bodies: Vec<String> = (0..N)
        .map(|i| {
            format!(
                "{{\"prompt\":[256,{},10],\"max_new\":8,\
                 \"temperature\":0.8,\"top_k\":8,\"seed\":{}}}",
                5 + i,
                100 + i
            )
        })
        .collect();

    for width in [1usize, 4] {
        pool::with_threads(width, || {
            let refs: Vec<&GenerateParams> = reqs.iter().collect();
            let (direct, _) =
                generate_batch(&bundle, &params, N, DECISION, &refs).unwrap();

            let engine = Arc::new(
                Engine::start(
                    bundle.clone(),
                    Arc::new(params.clone()),
                    ServeConfig { workers: 1, ..Default::default() },
                    DECISION,
                )
                .unwrap(),
            );
            let server =
                HttpServer::start(engine.clone(), test_config()).unwrap();
            let addr = server.local_addr();

            let streamed: Vec<Vec<u16>> = std::thread::scope(|s| {
                let handles: Vec<_> = bodies
                    .iter()
                    .map(|b| {
                        s.spawn(move || {
                            let (tokens, terminal) = sse_generate(addr, b);
                            assert_eq!(terminal, "done");
                            tokens
                        })
                    })
                    .collect();
                handles.into_iter().map(|h| h.join().unwrap()).collect()
            });

            assert_eq!(
                streamed, direct,
                "SSE streams != generate_batch at width {width}"
            );
            server.shutdown();
            drop(engine);
        });
    }
}

/// Validate the whole scrape as Prometheus text exposition format:
/// every family has HELP + TYPE before its samples, every sample line
/// is `name[{labels}] value` with a parseable value.
fn assert_prometheus_well_formed(text: &str) {
    let mut typed: Vec<String> = Vec::new();
    for line in text.lines() {
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix("# ") {
            let mut parts = rest.splitn(3, ' ');
            let keyword = parts.next().unwrap();
            let name = parts.next().expect("metric name after # keyword");
            assert!(
                keyword == "HELP" || keyword == "TYPE",
                "unknown comment keyword in {line:?}"
            );
            if keyword == "TYPE" {
                let kind = parts.next().expect("type value");
                assert!(
                    ["counter", "gauge", "histogram", "summary"]
                        .contains(&kind),
                    "{line:?}"
                );
                typed.push(name.to_string());
            }
            continue;
        }
        let (key, value) = line.rsplit_once(' ').expect("name SP value");
        assert!(
            value.parse::<f64>().is_ok()
                || ["+Inf", "-Inf", "NaN"].contains(&value),
            "unparseable value in {line:?}"
        );
        let name = key.split('{').next().unwrap();
        assert!(
            key.matches('{').count() == key.matches('}').count(),
            "unbalanced braces in {key:?}"
        );
        // a sample's family (histograms suffix _bucket/_sum/_count) must
        // have been TYPEd earlier in the scrape
        let family_typed = typed.iter().any(|t| {
            name == t
                || name == format!("{t}_bucket")
                || name == format!("{t}_sum")
                || name == format!("{t}_count")
        });
        assert!(family_typed, "sample {name:?} before its # TYPE header");
    }
}

/// Acceptance: `/metrics` serves the same numbers `Engine::stats()`
/// reports (requests, tokens, queue depth, latency histogram) — the
/// registry is global, so the comparison is over deltas around this
/// test's traffic while `knob_guard` keeps other engine tests out.
#[test]
fn metrics_endpoint_agrees_with_engine_stats() {
    let _g = pool::knob_guard();
    let (engine, server) = start_gateway(1, test_config());
    let addr = server.local_addr();

    let scrape = |addr| {
        let (status, body) = get(addr, "/metrics");
        assert_eq!(status, 200);
        String::from_utf8(body).expect("metrics scrape is UTF-8")
    };
    let before = scrape(addr);
    assert_prometheus_well_formed(&before);

    // traffic: 3 non-streamed + 2 streamed + 1 rejected
    for i in 0..3u64 {
        let (status, _) = post_json(
            addr,
            "/v1/generate",
            &format!("{{\"prompt\":[256,7],\"max_new\":5,\"seed\":{i}}}"),
        );
        assert_eq!(status, 200);
    }
    for i in 0..2u64 {
        let (tokens, terminal) = sse_generate(
            addr,
            &format!("{{\"prompt\":[256,9],\"max_new\":4,\"seed\":{i}}}"),
        );
        assert!(!tokens.is_empty());
        assert_eq!(terminal, "done");
    }
    let (status, _) =
        post_json(addr, "/v1/generate", "{\"prompt\":[1],\"max_new\":0}");
    assert_eq!(status, 400);

    // quiesce: a request's Done event is sent *before* the worker's
    // end-of-step accounting lands, so wait until two consecutive stats
    // reads agree before scraping
    let mut prev = (u64::MAX, u64::MAX);
    for _ in 0..200 {
        let s = engine.stats();
        let cur = (s.steps, s.tokens_generated);
        if s.completed == 5 && cur == prev {
            break;
        }
        prev = cur;
        std::thread::sleep(Duration::from_millis(10));
    }

    let after = scrape(addr);
    assert_prometheus_well_formed(&after);
    let stats = engine.stats();

    let delta = |name: &str| {
        sample_value(&after, name).unwrap_or(0.0)
            - sample_value(&before, name).unwrap_or(0.0)
    };
    // the engine was fresh at the `before` scrape, so deltas == stats
    assert_eq!(delta("engine_requests_total") as u64, stats.submitted);
    assert_eq!(stats.submitted, 5, "rejected request never reached submit");
    assert_eq!(delta("engine_completed_total") as u64, stats.completed);
    assert_eq!(stats.completed, 5);
    assert_eq!(
        delta("engine_tokens_generated_total") as u64,
        stats.tokens_generated
    );
    assert_eq!(delta("engine_steps_total") as u64, stats.steps);
    assert_eq!(
        delta("engine_blocks_skipped_total") as u64,
        stats.blocks_skipped
    );
    assert_eq!(
        delta("engine_rows_released_total") as u64,
        stats.rows_released
    );
    assert_eq!(
        delta("engine_request_latency_seconds_count") as u64,
        stats.completed,
        "one latency observation per completed request"
    );

    // queue depth: absolute gauge, drained after traffic — and exactly
    // what Engine::stats() reports
    assert_eq!(stats.queue_depth, 0);
    assert_eq!(
        sample_value(&after, "engine_queue_depth"),
        Some(stats.queue_depth as f64)
    );
    assert_eq!(sample_value(&after, "engine_active_rows"), Some(0.0));

    // latency histogram: cumulative buckets non-decreasing, +Inf == count
    let buckets: Vec<f64> = after
        .lines()
        .filter(|l| l.starts_with("engine_request_latency_seconds_bucket"))
        .map(|l| l.rsplit_once(' ').unwrap().1.parse::<f64>().unwrap())
        .collect();
    assert!(!buckets.is_empty());
    assert!(
        buckets.windows(2).all(|w| w[0] <= w[1]),
        "buckets must be cumulative: {buckets:?}"
    );
    assert_eq!(
        *buckets.last().unwrap(),
        sample_value(&after, "engine_request_latency_seconds_count").unwrap()
    );

    // TTFT / inter-token are first-class: exactly one first-token
    // observation per completed request, one gap per non-first token
    assert_eq!(
        delta("engine_ttft_seconds_count") as u64,
        stats.completed,
        "one TTFT observation per completed request"
    );
    assert_eq!(
        delta("engine_inter_token_seconds_count") as u64,
        stats.tokens_generated - stats.completed,
        "every non-first token contributes one inter-token gap"
    );

    // sketch summaries: `/metrics` serves the same absolute numbers
    // `Engine::stats()` reports — both read the process-global sketches,
    // and the engine is quiesced between the scrape and the stats read
    for (family, s) in [
        ("engine_request_latency_sketch_seconds", stats.request_latency),
        ("engine_ttft_sketch_seconds", stats.ttft),
        ("engine_inter_token_sketch_seconds", stats.inter_token),
    ] {
        assert!(s.count > 0, "{family} saw this test's traffic");
        assert_eq!(
            sample_value(&after, &format!("{family}_count")),
            Some(s.count as f64),
            "{family} count"
        );
        for (q, v) in
            [("0.5", s.p50_s), ("0.95", s.p95_s), ("0.99", s.p99_s)]
        {
            assert_eq!(
                sample_value(
                    &after,
                    &format!("{family}{{quantile=\"{q}\"}}")
                ),
                Some(v),
                "{family} q{q}"
            );
        }
    }
    // and the one-line snapshot carries the same percentile tail
    let line = stats.snapshot_line();
    assert!(line.contains("req p50/p95/p99"), "{line}");
    assert!(line.contains("ttft"), "{line}");

    // process-level families registered by the gateway's engine
    assert!(
        sample_value(&after, "process_uptime_seconds").unwrap_or(-1.0)
            >= 0.0
    );
    assert!(
        after.contains("build_info{"),
        "build_info gauge with version/features labels"
    );

    // the gateway instruments itself too
    assert!(delta("gateway_connections_total") >= 6.0);
    assert!(
        sample_value(
            &after,
            "gateway_requests_total{method=\"POST\",\
             path=\"/v1/generate\",status=\"200\"}"
        )
        .unwrap_or(0.0)
            >= 5.0
    );
    assert!(
        sample_value(
            &after,
            "gateway_requests_total{method=\"GET\",\
             path=\"/metrics\",status=\"200\"}"
        )
        .unwrap_or(0.0)
            >= 1.0,
        "scrapes themselves are counted, with the method label"
    );

    // the pool's region accounting showed up (decode ran kernels)
    assert!(
        sample_value(&after, "pool_regions_serial_total").unwrap_or(0.0)
            + sample_value(&after, "pool_regions_parallel_total")
                .unwrap_or(0.0)
            > 0.0
    );

    server.shutdown();
    drop(engine);
}

/// Flight recorder: per-request traces are opt-in on the wire
/// (`"trace": true`), and the engine keeps a bounded ring of recent
/// request records served at `GET /v1/debug/requests`.
#[test]
fn flight_recorder_ring_and_trace_opt_in() {
    let _g = pool::knob_guard();
    let (engine, server) = start_gateway(1, test_config());
    let addr = server.local_addr();

    // default: usage carries no trace (the wire format stays stable)
    let (status, body) = post_json(
        addr,
        "/v1/generate",
        "{\"prompt\":[256,3],\"max_new\":4,\"seed\":1}",
    );
    assert_eq!(status, 200);
    let j = Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
    assert!(j.get("usage").unwrap().get("trace").is_none());

    // opt-in: usage carries the full per-request trace
    let (status, body) = post_json(
        addr,
        "/v1/generate",
        "{\"prompt\":[256,3,7,9],\"max_new\":6,\"seed\":2,\"trace\":true}",
    );
    assert_eq!(status, 200, "{}", String::from_utf8_lossy(&body));
    let j = Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
    let n_tokens = j.get("tokens").unwrap().as_arr().unwrap().len();
    let trace = j
        .get("usage")
        .unwrap()
        .get("trace")
        .expect("trace requested, trace served");
    assert!(trace.req_usize("prefill_chunks").unwrap() >= 1);
    assert!(trace.req_f64("queue_ms").unwrap() >= 0.0);
    assert!(trace.req_f64("ttft_ms").unwrap() >= 0.0);
    let invoked = trace.req_usize("blocks_invoked").unwrap();
    let skipped = trace.req_usize("blocks_skipped").unwrap();
    assert!(invoked > 0, "unrouted blocks always run");
    let sf = trace.req_f64("skip_fraction").unwrap();
    let want = skipped as f64 / (invoked + skipped).max(1) as f64;
    assert!((sf - want).abs() < 1e-9, "{sf} vs {want}");
    let gaps = trace.get("decode_gaps").unwrap();
    assert_eq!(
        gaps.req_usize("count").unwrap(),
        n_tokens - 1,
        "one gap per non-first token"
    );
    // summary order holds: p50 <= p95 <= max
    let (p50, p95, max) = (
        gaps.req_f64("p50_ms").unwrap(),
        gaps.req_f64("p95_ms").unwrap(),
        gaps.req_f64("max_ms").unwrap(),
    );
    assert!(p50 <= p95 + 1e-9 && p95 <= max + 1e-9, "{p50} {p95} {max}");

    // the ring: finish accounting can land just after the client's
    // response is written, so poll briefly
    let mut recs: Vec<Json> = Vec::new();
    for _ in 0..200 {
        let (status, body) = get(addr, "/v1/debug/requests");
        assert_eq!(status, 200);
        let j = Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
        recs = j.get("requests").unwrap().as_arr().unwrap().to_vec();
        if recs.len() >= 2 {
            break;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    assert_eq!(recs.len(), 2, "both requests recorded, opt-in or not");
    // newest-first by admission sequence
    assert!(
        recs[0].req_usize("seq").unwrap() > recs[1].req_usize("seq").unwrap()
    );
    for r in &recs {
        assert!(["eos", "stop", "max_tokens"]
            .contains(&r.req_str("outcome").unwrap().as_str()));
        assert!(r.req_usize("decode_tokens").unwrap() >= 1);
        assert!(r.req_f64("latency_ms").unwrap() > 0.0);
        let t = r.get("trace").expect("every record carries a trace");
        assert!(t.req_usize("blocks_invoked").unwrap() > 0);
        // per-layer routing ledger: one [invoked, skipped] pair per
        // model layer, summing exactly to the aggregate pair
        let layers = t
            .get("layer_blocks")
            .and_then(Json::as_arr)
            .expect("layer_blocks array");
        assert_eq!(layers.len(), 4, "one entry per model layer");
        let (mut inv, mut skip) = (0usize, 0usize);
        for lb in layers {
            let pair = lb.as_arr().expect("[invoked, skipped] pair");
            assert_eq!(pair.len(), 2);
            inv += pair[0].as_usize().unwrap();
            skip += pair[1].as_usize().unwrap();
        }
        assert_eq!(inv, t.req_usize("blocks_invoked").unwrap());
        assert_eq!(skip, t.req_usize("blocks_skipped").unwrap());
    }

    server.shutdown();
    drop(engine);
}

/// The debug surfaces added with the span tracer: `?n=` bounds the
/// flight-recorder dump (non-numeric → typed 400, never a silent
/// default), and `GET /v1/debug/trace` serves the live span ring as
/// parseable Chrome trace-event JSON carrying the request-path spans.
#[test]
fn debug_trace_endpoint_and_requests_limit() {
    use mod_transformer::util::trace;
    let _g = pool::knob_guard();
    // the ring is process-global; other tests tolerate foreign events
    trace::enable(trace::DEFAULT_CAPACITY);
    let (engine, server) = start_gateway(1, test_config());
    let addr = server.local_addr();

    for i in 0..2u64 {
        let (status, body) = post_json(
            addr,
            "/v1/generate",
            &format!("{{\"prompt\":[256,5],\"max_new\":3,\"seed\":{i}}}"),
        );
        assert_eq!(status, 200, "{}", String::from_utf8_lossy(&body));
    }
    // one streamed request so the `sse_write` span lands on the ring too
    sse_generate(addr, "{\"prompt\":[256,5],\"max_new\":3,\"seed\":2}");

    // finish accounting can land just after the response: poll the ring
    let mut all: Vec<Json> = Vec::new();
    for _ in 0..200 {
        let (status, body) = get(addr, "/v1/debug/requests");
        assert_eq!(status, 200);
        let j = Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
        all = j.get("requests").unwrap().as_arr().unwrap().to_vec();
        if all.len() >= 3 {
            break;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    assert_eq!(all.len(), 3);

    // ?n= keeps the newest-first head of the same list
    let (status, body) = get(addr, "/v1/debug/requests?n=2");
    assert_eq!(status, 200);
    let j = Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
    let limited = j.get("requests").unwrap().as_arr().unwrap();
    assert_eq!(limited.len(), 2);
    assert_eq!(
        limited[0].req_usize("seq").unwrap(),
        all[0].req_usize("seq").unwrap()
    );
    // n past the ring size is the whole ring; n=0 is legal and empty
    let (_, body) = get(addr, "/v1/debug/requests?n=999");
    let j = Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
    assert_eq!(j.get("requests").unwrap().as_arr().unwrap().len(), 3);
    let (_, body) = get(addr, "/v1/debug/requests?n=0");
    let j = Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
    assert!(j.get("requests").unwrap().as_arr().unwrap().is_empty());

    // non-numeric limit: typed 400
    let (status, body) = get(addr, "/v1/debug/requests?n=bogus");
    assert_eq!(status, 400, "{}", String::from_utf8_lossy(&body));
    let j = Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
    let err = j.get("error").expect("typed error body");
    assert_eq!(err.req_str("kind").unwrap(), "rejected");
    assert!(err.req_str("message").unwrap().contains("non-negative"));

    // the live span ring over the wire
    let (status, body) = get(addr, "/v1/debug/trace");
    assert_eq!(status, 200);
    let dump = Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
    let events = dump.get("traceEvents").unwrap().as_arr().unwrap();
    assert!(!events.is_empty(), "tracing was on while requests ran");
    let names: Vec<&str> = events
        .iter()
        .filter(|e| e.get("ph").and_then(Json::as_str) == Some("X"))
        .filter_map(|e| e.get("name").and_then(Json::as_str))
        .collect();
    for want in ["admit", "decode_step", "sample", "sse_write"] {
        assert!(names.contains(&want), "missing span {want:?} in {names:?}");
    }
    // every complete event carries the Chrome timing/track fields
    for e in events {
        match e.get("ph").and_then(Json::as_str) {
            Some("X") => {
                assert!(e.get("ts").and_then(Json::as_f64).is_some());
                assert!(e.get("dur").and_then(Json::as_f64).unwrap() >= 0.0);
                assert!(e.get("tid").and_then(Json::as_u64).is_some());
            }
            Some("M") => {}
            other => panic!("unexpected phase {other:?}"),
        }
    }
    trace::disable();
    trace::clear();

    server.shutdown();
    drop(engine);
}

/// Admission control over the wire: with the single session row busy
/// and the queue at its cap, the gateway answers 429 with a numeric
/// `Retry-After` header, a typed `overloaded` JSON body (including
/// `retry_after_s`), and a per-class `engine_shed_total` counter in
/// `/metrics`. Priority rides both the JSON `priority` field and the
/// `X-Priority` header; unknown class names are a 400, never a silent
/// downgrade.
#[test]
fn overload_returns_429_with_retry_after_and_class_metrics() {
    let _g = pool::knob_guard();
    let bundle = open("mod_tiny_http");
    let params = Arc::new(bundle.init_params().unwrap());
    let engine = Arc::new(
        Engine::start(
            bundle,
            params,
            ServeConfig {
                decode_batches: vec![1],
                workers: 1,
                queue_cap: 1,
                ..Default::default()
            },
            DECISION,
        )
        .unwrap(),
    );
    let server = HttpServer::start(engine.clone(), test_config()).unwrap();
    let addr = server.local_addr();

    // unknown class names: typed 400 from the JSON field ...
    let (status, body) = post_json(
        addr,
        "/v1/generate",
        "{\"prompt\":[256],\"max_new\":2,\"priority\":\"vip\"}",
    );
    assert_eq!(status, 400, "{}", String::from_utf8_lossy(&body));
    // ... and from the X-Priority header
    let ok_body = "{\"prompt\":[256],\"max_new\":2,\"seed\":4}";
    let raw = format!(
        "POST /v1/generate HTTP/1.1\r\nX-Priority: vip\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{ok_body}",
        ok_body.len()
    );
    let (head, _) = split_response(&exchange(addr, raw.as_bytes()));
    assert_eq!(status_of(&head), 400, "{head}");

    // a long stream occupies the single row ...
    let long = std::thread::spawn(move || {
        sse_generate(
            addr,
            "{\"prompt\":[256,3],\"max_new\":60,\"temperature\":0.9,\
             \"seed\":1}",
        )
    });
    // ... wait until it has been admitted (left the queue)
    for _ in 0..500 {
        let s = engine.stats();
        if s.submitted >= 1 && s.queue_depth == 0 {
            break;
        }
        std::thread::sleep(Duration::from_millis(2));
    }
    // one queued request fills the whole cap
    let queued = std::thread::spawn(move || {
        post_json(
            addr,
            "/v1/generate",
            "{\"prompt\":[256,5],\"max_new\":2,\"seed\":2}",
        )
    });
    for _ in 0..500 {
        if engine.stats().queue_depth >= 1 {
            break;
        }
        std::thread::sleep(Duration::from_millis(2));
    }

    // the next request sheds: 429 + numeric Retry-After + typed body
    let shed_body =
        "{\"prompt\":[256,7],\"max_new\":2,\"seed\":3,\"priority\":\"bulk\"}";
    let raw = format!(
        "POST /v1/generate HTTP/1.1\r\nContent-Length: {}\r\n\
         Connection: close\r\n\r\n{shed_body}",
        shed_body.len()
    );
    let (head, resp_body) = split_response(&exchange(addr, raw.as_bytes()));
    assert_eq!(status_of(&head), 429, "{head}");
    let retry: u64 = header_of(&head, "retry-after")
        .expect("429 carries Retry-After")
        .parse()
        .expect("Retry-After is whole seconds");
    assert!(retry >= 1);
    let j = Json::parse(std::str::from_utf8(&resp_body).unwrap()).unwrap();
    let err = j.get("error").expect("typed error body");
    assert_eq!(err.req_str("kind").unwrap(), "overloaded");
    assert!(err.req_str("message").unwrap().contains("queue full"));
    assert!(err.req_f64("retry_after_s").unwrap() >= 1.0);

    // the shed is visible per class in /metrics
    let (status, scrape) = get(addr, "/metrics");
    assert_eq!(status, 200);
    let scrape = String::from_utf8(scrape).unwrap();
    assert!(
        sample_value(&scrape, "engine_shed_total{class=\"bulk\"}")
            .unwrap_or(0.0)
            >= 1.0,
        "per-class shed counter exported"
    );

    // the admitted requests were untouched by the shed
    let (tokens, terminal) = long.join().expect("long stream");
    assert_eq!(terminal, "done");
    assert!(!tokens.is_empty());
    let (status, _) = queued.join().expect("queued request");
    assert_eq!(status, 200, "queued request completed after the stream");

    // a well-formed X-Priority header is accepted and counted per class
    let raw = format!(
        "POST /v1/generate HTTP/1.1\r\nX-Priority: interactive\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{ok_body}",
        ok_body.len()
    );
    let (head, _) = split_response(&exchange(addr, raw.as_bytes()));
    assert_eq!(status_of(&head), 200, "{head}");
    let (_, scrape) = get(addr, "/metrics");
    let scrape = String::from_utf8(scrape).unwrap();
    assert!(
        sample_value(
            &scrape,
            "engine_class_requests_total{class=\"interactive\"}"
        )
        .unwrap_or(0.0)
            >= 1.0,
        "per-class submit counter exported"
    );

    server.shutdown();
    drop(engine);
}

/// Graceful drain: a stream in flight when shutdown starts runs to
/// completion, then the gateway joins its threads and returns.
#[test]
fn shutdown_drains_inflight_streams() {
    let _g = pool::knob_guard();
    let (engine, server) = start_gateway(1, test_config());
    let addr = server.local_addr();

    let client = std::thread::spawn(move || {
        sse_generate(
            addr,
            "{\"prompt\":[256,3],\"max_new\":16,\"seed\":5}",
        )
    });
    // let the stream actually start before draining
    std::thread::sleep(Duration::from_millis(50));
    server.shutdown();

    let (tokens, terminal) = client.join().expect("client thread");
    assert_eq!(terminal, "done", "in-flight stream completed during drain");
    assert!(!tokens.is_empty());

    // post-drain connections are refused or reset, never half-served
    let refused = TcpStream::connect(addr)
        .map(|mut s| {
            let _ = s.write_all(b"GET /healthz HTTP/1.1\r\n\r\n");
            let mut buf = Vec::new();
            // server side is gone: read yields 0 bytes or an error
            matches!(s.read_to_end(&mut buf), Ok(0) | Err(_))
        })
        .unwrap_or(true);
    assert!(refused, "listener must be closed after shutdown");

    drop(engine);
}
