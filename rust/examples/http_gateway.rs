//! Serve a MoD bundle over the zero-dependency HTTP/SSE gateway and
//! print a curl walkthrough, then live one-line stats snapshots (the
//! same numbers `GET /metrics` exposes in Prometheus format).
//!
//! Run: `cargo run --release --example http_gateway -- \
//!         [--bundle mod_tiny] [--port 8080] [--workers 0] \
//!         [--decision router] [--stats-every-ms 5000]`
//!
//! Then, from another shell:
//!
//! ```bash
//! curl -s localhost:8080/healthz
//! curl -s -X POST localhost:8080/v1/generate \
//!      -d '{"prompt":[256,7,10],"max_new":16,"seed":3}'
//! curl -sN -X POST 'localhost:8080/v1/generate?stream=1' \
//!      -d '{"prompt":[256,7,10],"max_new":16,"seed":3}'
//! curl -s localhost:8080/metrics | grep engine_
//! ```

use std::sync::Arc;

use mod_transformer::config::ServeConfig;
use mod_transformer::runtime::open_bundle;
use mod_transformer::serve::{HttpConfig, HttpServer, RoutingDecision};
use mod_transformer::util::Args;

fn main() -> mod_transformer::Result<()> {
    let args = Args::parse(std::env::args().skip(1), &[])?;
    let bundle_name = args.str_or("bundle", "mod_tiny");
    let port = args.usize_or("port", 8080)?;
    let stats_every = args.u64_or("stats-every-ms", 5000)?.max(500);
    let decision = match args.str_or("decision", "router").as_str() {
        "predictor" => RoutingDecision::Predictor,
        "always" => RoutingDecision::AlwaysOn,
        _ => RoutingDecision::RouterThreshold,
    };

    let bundle = open_bundle(std::path::Path::new("artifacts"), &bundle_name)?;
    let params = Arc::new(bundle.init_params()?);
    let engine = Arc::new(mod_transformer::serve::Engine::start(
        bundle,
        params,
        ServeConfig {
            workers: args.usize_or("workers", 0)?,
            ..Default::default()
        },
        decision,
    )?);

    let server = HttpServer::start(
        engine.clone(),
        HttpConfig { addr: format!("127.0.0.1:{port}"), ..Default::default() },
    )?;
    let addr = server.local_addr();
    println!("serving {bundle_name} on http://{addr}");
    println!();
    println!("try it:");
    println!("  curl -s {addr}/healthz");
    println!(
        "  curl -s -X POST {addr}/v1/generate \\\n       \
         -d '{{\"prompt\":[256,7,10],\"max_new\":16,\"seed\":3}}'"
    );
    println!(
        "  curl -sN -X POST '{addr}/v1/generate?stream=1' \\\n       \
         -d '{{\"prompt\":[256,7,10],\"max_new\":16,\"seed\":3}}'"
    );
    println!("  curl -s {addr}/metrics | grep engine_");
    println!();
    println!("(ctrl-c to stop; snapshots every {stats_every} ms)");

    loop {
        std::thread::sleep(std::time::Duration::from_millis(stats_every));
        println!("{}", engine.stats().snapshot_line());
    }
}
