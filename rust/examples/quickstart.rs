//! Quickstart: the whole MoD stack in ~60 lines — fully offline.
//!
//! Opens the `mod_tiny` bundle (AOT artifacts if present, otherwise a
//! synthetic in-memory bundle on the native CPU backend), trains for a
//! handful of steps on the synthetic corpus, evaluates under the
//! training-style top-k routing, and generates a few tokens through the
//! layer-sliced decode runtime — demonstrating that routed-around blocks
//! are *really skipped* (see the skip fraction it prints).
//!
//! Run: `cargo run --release --example quickstart`

use mod_transformer::coordinator::{Trainer, TrainerOptions};
use mod_transformer::data::{BatchIter, CorpusSpec, MarkovCorpus};
use mod_transformer::runtime::open_bundle;
use mod_transformer::serve::{DecodeSession, RoutingDecision};

fn main() -> mod_transformer::Result<()> {
    // 1. open the bundle (artifacts if built, synthetic preset otherwise)
    let bundle = open_bundle(std::path::Path::new("artifacts"), "mod_tiny")?;
    println!(
        "bundle {} on {}: {} params, routed layers {:?}",
        bundle.manifest.name,
        bundle.backend().platform(),
        bundle.manifest.n_params,
        bundle.manifest.routed_layers
    );

    // 2. train a few steps on the synthetic corpus
    let corpus = MarkovCorpus::new(CorpusSpec::default(), 7);
    let data = BatchIter::new(
        corpus,
        bundle.manifest.train.batch_size,
        bundle.manifest.model.seq_len,
    );
    let mut trainer = Trainer::new(bundle.clone(), data, None)?;
    let outcome = trainer.run(&TrainerOptions {
        steps: Some(20),
        log_every: 5,
        run_dir: "runs/quickstart".into(),
        ..Default::default()
    })?;
    println!(
        "trained {} steps: loss {:.3}, {:.2} steps/s",
        outcome.steps, outcome.final_loss, outcome.steps_per_sec
    );

    // 3. held-out evaluation (top-k routing, as in training)
    let eval = trainer.evaluate("topk", 2)?;
    println!(
        "eval: ce {:.3}, predictor accuracy {:.2}, participation {:.3}",
        eval.ce, eval.pred_acc, eval.participation
    );

    // 4. generate through the layer-sliced decode runtime
    let params = trainer.params()?;
    let mut session = DecodeSession::new(
        &bundle,
        &params,
        1,
        RoutingDecision::RouterThreshold,
    )?;
    let mut tok = mod_transformer::data::BOS as i32;
    let mut toks = Vec::new();
    for _ in 0..24 {
        let logits = session.step(&[tok], &[true])?;
        let mut best = 0;
        for (i, &v) in logits.iter().enumerate() {
            if v > logits[best] {
                best = i;
            }
        }
        tok = best as i32;
        toks.push(best);
    }
    let rep = session.report();
    println!("generated: {toks:?}");
    println!(
        "decode: {:.0}% of blocks skipped, {} capacity drops, {:.2e} \
         FLOPs/token",
        100.0 * rep.skip_fraction(),
        rep.capacity_drops,
        rep.total_flops / rep.tokens_generated.max(1) as f64
    );
    Ok(())
}
