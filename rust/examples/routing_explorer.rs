//! Routing explorer: train briefly, then visualize which tokens the MoD
//! router sends *through* blocks vs *around* them (paper figs 1 & 5).
//!
//! Uses the corpus's ground-truth difficulty labels (deterministic phrase
//! continuations vs high-entropy Markov draws) to test the paper's §4.1
//! hypothesis that routed-through tokens correlate with harder
//! predictions. Also demos the from-scratch BPE substrate by reporting
//! routing statistics over merged-token text.
//!
//! Run: `cargo run --release --example routing_explorer -- [--steps 150]`

use mod_transformer::analysis;
use mod_transformer::coordinator::{Trainer, TrainerOptions};
use mod_transformer::data::bpe::Bpe;
use mod_transformer::data::tokenizer::Tokenizer;
use mod_transformer::data::{BatchIter, CorpusSpec, MarkovCorpus};
use mod_transformer::runtime::open_bundle;
use mod_transformer::util::Args;

fn main() -> mod_transformer::Result<()> {
    let args = Args::parse(std::env::args().skip(1), &[])?;
    let steps = args.u64_or("steps", 150)?;

    let bundle = open_bundle(std::path::Path::new("artifacts"), "mod_tiny")?;
    let corpus = MarkovCorpus::new(CorpusSpec::default(), 7);
    let data = BatchIter::new(
        corpus.clone(),
        bundle.manifest.train.batch_size,
        bundle.manifest.model.seq_len,
    );

    println!("training mod_tiny for {steps} steps to shape the router...");
    let mut trainer = Trainer::new(bundle.clone(), data, None)?;
    trainer.run(&TrainerOptions {
        steps: Some(steps),
        log_every: (steps / 5).max(1),
        run_dir: "runs/routing_explorer".into(),
        ..Default::default()
    })?;
    let params = trainer.params()?;

    println!("\ncollecting routing decisions over held-out sequences...");
    let eval_corpus = MarkovCorpus::new(CorpusSpec::default(), 8);
    let maps =
        analysis::collect_routing_maps(&bundle, &params, &eval_corpus, 4, 64)?;

    println!("\nrouting map (sequence 0, '#'=through, '.'=around, \
              '^'=high-entropy position):");
    println!("{}", analysis::render_map(&maps[0], 64));

    let hist = analysis::histogram(
        maps.iter()
            .flat_map(|m| m.router_sigmoids.iter().flatten().copied()),
        20,
    );
    println!(
        "router sigmoids > 0.5: {:.1}% (aux BCE targets capacity = {:.1}%)",
        100.0 * hist.frac_above_half,
        100.0 * bundle.manifest.model.capacity_frac
    );

    let corr = analysis::difficulty_correlation(&maps);
    println!(
        "P(through | hard) = {:.3} vs P(through | easy) = {:.3}  \
         [{} hard / {} easy]",
        corr.p_route_hard, corr.p_route_easy, corr.n_hard, corr.n_easy
    );

    // --- BPE substrate demo: routing over merged tokens ---
    println!("\n--- BPE demo (from-scratch substrate) ---");
    let sample: String = {
        // decode a corpus sequence into printable bytes for BPE training
        let toks = corpus.sequence(0, 2048);
        toks.iter()
            .filter(|&&t| t < 256)
            .map(|&t| (b'a' + (t % 26) as u8) as char)
            .collect()
    };
    let bpe = Bpe::train(&sample, 64);
    let encoded = bpe.encode(&sample[..256.min(sample.len())]);
    println!(
        "trained {} merges; sample compresses {} bytes -> {} tokens \
         ({:.2}x)",
        bpe.n_merges(),
        256.min(sample.len()),
        encoded.len(),
        256.0 / encoded.len() as f64
    );
    Ok(())
}
