//! End-to-end training driver (the DESIGN.md validation workload).
//!
//! Trains a baseline transformer and a 12.5%-capacity interleaved MoD
//! transformer of identical width/depth for a few hundred steps on the
//! synthetic corpus, logging both loss curves, then evaluates both on a
//! held-out split and reports the paper's headline comparison: MoD loss vs
//! baseline loss, MoD steps/sec vs baseline steps/sec, FLOPs per forward
//! pass. Results land in `runs/train_tiny_lm/` (metrics.jsonl + .csv per
//! model) and are summarized in EXPERIMENTS.md.
//!
//! Run: `cargo run --release --example train_tiny_lm [-- --steps N]`

use mod_transformer::coordinator::{Trainer, TrainerOptions};
use mod_transformer::data::{BatchIter, CorpusSpec, MarkovCorpus};
use mod_transformer::flops;
use mod_transformer::runtime::open_bundle;
use mod_transformer::util::Args;

fn main() -> mod_transformer::Result<()> {
    let args = Args::parse(std::env::args().skip(1), &[])?;
    let steps = args.u64_or("steps", 300)?;
    let mut results = Vec::new();
    for name in ["baseline_tiny", "mod_tiny"] {
        let bundle = open_bundle(std::path::Path::new("artifacts"), name)?;
        let corpus = MarkovCorpus::new(CorpusSpec::default(), 7);
        let data = BatchIter::new(
            corpus,
            bundle.manifest.train.batch_size,
            bundle.manifest.model.seq_len,
        );
        println!(
            "=== training {name}: {} params, rel FLOPs/fwd {:.3}, {steps} steps ===",
            bundle.manifest.n_params,
            flops::relative_flops(&bundle.manifest.model),
        );
        let mut trainer = Trainer::new(bundle.clone(), data, None)?;
        let outcome = trainer.run(&TrainerOptions {
            steps: Some(steps),
            log_every: 10,
            ckpt_every: 0,
            run_dir: format!("runs/train_tiny_lm/{name}").into(),
            resume: None,
        })?;
        let eval = trainer.evaluate("topk", 4)?;
        println!(
            "{name}: final train loss {:.4} (ce {:.4}), held-out ce {:.4}, \
             {:.2} steps/s",
            outcome.final_loss, outcome.final_ce, eval.ce,
            outcome.steps_per_sec
        );
        // print the loss curve coarsely from the metrics file
        let rows = mod_transformer::coordinator::metrics::load_jsonl(
            &outcome.metrics_path,
        )?;
        print!("loss curve: ");
        for r in rows.iter().step_by((rows.len() / 8).max(1)) {
            print!("{:.2}@{} ", r.values.get("ce").copied().unwrap_or(0.0), r.step);
        }
        println!();
        results.push((
            name,
            outcome.final_ce,
            eval.ce,
            outcome.steps_per_sec,
            flops::relative_flops(&bundle.manifest.model),
        ));
    }

    println!("\n=== summary (paper claim: MoD matches/beats baseline while \
              using fewer FLOPs per forward pass) ===");
    for (name, train_ce, eval_ce, sps, rel) in &results {
        println!(
            "  {name:<14} train ce {train_ce:.4}  held-out ce {eval_ce:.4}  \
             {sps:.2} steps/s  {rel:.3}x FLOPs/fwd"
        );
    }
    if let [base, modr] = &results[..] {
        println!(
            "\nMoD vs baseline: Δheld-out-ce {:+.4}, step-speed x{:.2}, \
             FLOPs/fwd x{:.2}",
            modr.2 - base.2,
            modr.3 / base.3,
            modr.4 / base.4
        );
    }
    Ok(())
}
