//! Serving demo: the dynamic-batching MoD server under concurrent load.
//!
//! Spawns the batcher worker, submits a stream of prompts (optionally from
//! a trained checkpoint), and reports per-request latency percentiles,
//! aggregate throughput, the measured block-skip fraction, capacity drops,
//! and the KV-cache memory saving vs a vanilla cache — the serving-side
//! view of the paper's decode-time claims.
//!
//! Run: `cargo run --release --example serve_mod -- \
//!         [--bundle mod_tiny] [--ckpt runs/.../final.ckpt] \
//!         [--requests 12] [--max-new 24] [--decision router]`

use std::sync::Arc;

use mod_transformer::config::ServeConfig;
use mod_transformer::data::{CorpusSpec, MarkovCorpus};
use mod_transformer::runtime::open_bundle;
use mod_transformer::serve::batcher::{Request, Server};
use mod_transformer::serve::RoutingDecision;
use mod_transformer::util::Args;

fn main() -> mod_transformer::Result<()> {
    let args = Args::parse(std::env::args().skip(1), &[])?;
    let bundle_name = args.str_or("bundle", "mod_tiny");
    let n_requests = args.usize_or("requests", 12)?;
    let max_new = args.usize_or("max-new", 24)?;
    let decision = match args.str_or("decision", "router").as_str() {
        "predictor" => RoutingDecision::Predictor,
        "always" => RoutingDecision::AlwaysOn,
        _ => RoutingDecision::RouterThreshold,
    };

    let bundle = open_bundle(std::path::Path::new("artifacts"), &bundle_name)?;
    let params = Arc::new(match args.opt("ckpt") {
        Some(path) => {
            let by_name = mod_transformer::coordinator::checkpoint::load(
                std::path::Path::new(path),
            )?;
            bundle.order_params(
                by_name
                    .into_iter()
                    .filter(|(k, _)| {
                        !k.starts_with("m::")
                            && !k.starts_with("v::")
                            && k != "__step"
                    })
                    .collect(),
            )?
        }
        None => bundle.init_params()?,
    });

    println!(
        "serving {bundle_name} ({} params), decision={decision:?}, \
         compiled batches {:?}",
        bundle.manifest.n_params, bundle.manifest.decode_batches
    );

    let server = Server::spawn(
        bundle.clone(),
        params,
        ServeConfig { batch_wait_ms: 5, ..Default::default() },
        decision,
    );

    // submit a burst of prompts (the batcher groups them into sessions)
    let corpus = MarkovCorpus::new(CorpusSpec::default(), 99);
    let pendings: Vec<_> = (0..n_requests)
        .map(|i| {
            server.submit(Request {
                prompt: corpus.sequence(i as u64, 8),
                max_new,
                temperature: 0.8,
                top_k: 32,
                seed: i as u64,
            })
        })
        .collect::<mod_transformer::Result<_>>()?;

    let mut latencies = Vec::new();
    for (i, p) in pendings.into_iter().enumerate() {
        let resp = p.wait()?;
        latencies.push(resp.latency.as_secs_f64());
        if i < 3 {
            println!(
                "  request {i}: {} prompt + {} generated tokens in {:.2}s",
                resp.prefill_tokens,
                resp.decode_tokens,
                resp.latency.as_secs_f64()
            );
        }
    }
    latencies.sort_by(|a, b| a.total_cmp(b));

    let stats = server.stats();
    let p = |q: f64| latencies[((latencies.len() - 1) as f64 * q) as usize];
    println!("\n=== server report ===");
    println!(
        "requests: {} in {} batches | throughput {:.1} tok/s",
        stats.requests, stats.batches, stats.tokens_per_sec()
    );
    println!(
        "latency p50 {:.2}s  p90 {:.2}s  p99 {:.2}s",
        p(0.5), p(0.9), p(0.99)
    );
    println!(
        "MoD effect: {:.0}% of block invocations skipped, {} capacity \
         drops, {:.2e} FLOPs/token",
        100.0 * stats.skip_fraction(),
        stats.capacity_drops,
        stats.total_flops / stats.tokens_generated.max(1) as f64
    );
    server.shutdown();
    Ok(())
}
