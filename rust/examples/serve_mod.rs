//! Serving demo: the continuously-batched MoD engine under concurrent
//! load.
//!
//! Starts the [`Engine`] (persistent decode sessions whose rows are a
//! slot pool), submits a burst of prompts, streams the first request's
//! tokens as they land, and reports per-request latency percentiles,
//! aggregate throughput, mid-flight admissions (the continuous-batching
//! proof), the measured block-skip fraction, capacity drops, and the
//! KV-cache memory saving vs a vanilla cache — the serving-side view of
//! the paper's decode-time claims.
//!
//! Run: `cargo run --release --example serve_mod -- \
//!         [--bundle mod_tiny] [--ckpt runs/.../final.ckpt] \
//!         [--requests 12] [--max-new 24] [--decision router] \
//!         [--deadline-ms 30000]`

use std::io::Write as _;
use std::sync::Arc;

use mod_transformer::config::ServeConfig;
use mod_transformer::data::{CorpusSpec, MarkovCorpus};
use mod_transformer::runtime::open_bundle;
use mod_transformer::serve::{Engine, Event, GenerateParams, RoutingDecision};
use mod_transformer::util::Args;

fn main() -> mod_transformer::Result<()> {
    let args = Args::parse(std::env::args().skip(1), &[])?;
    let bundle_name = args.str_or("bundle", "mod_tiny");
    let n_requests = args.usize_or("requests", 12)?;
    let max_new = args.usize_or("max-new", 24)?;
    let deadline_ms = args.opt_u64("deadline-ms")?;
    let decision = match args.str_or("decision", "router").as_str() {
        "predictor" => RoutingDecision::Predictor,
        "always" => RoutingDecision::AlwaysOn,
        _ => RoutingDecision::RouterThreshold,
    };

    let bundle = open_bundle(std::path::Path::new("artifacts"), &bundle_name)?;
    let params = Arc::new(match args.opt("ckpt") {
        Some(path) => {
            let by_name = mod_transformer::coordinator::checkpoint::load(
                std::path::Path::new(path),
            )?;
            bundle.order_params(
                by_name
                    .into_iter()
                    .filter(|(k, _)| {
                        !k.starts_with("m::")
                            && !k.starts_with("v::")
                            && k != "__step"
                    })
                    .collect(),
            )?
        }
        None => bundle.init_params()?,
    });

    println!(
        "serving {bundle_name} ({} params), decision={decision:?}, \
         compiled batches {:?}",
        bundle.manifest.n_params, bundle.manifest.decode_batches
    );

    let engine = Engine::start(
        bundle.clone(),
        params,
        ServeConfig::default(),
        decision,
    )?;

    // submit a burst of prompts; the engine admits each into a session
    // row the moment one frees up — no batch boundaries, no drain bubble
    let corpus = MarkovCorpus::new(CorpusSpec::default(), 99);
    let gens: Vec<_> = (0..n_requests)
        .map(|i| {
            let mut p = GenerateParams::new(corpus.sequence(i as u64, 8))
                .max_new(max_new)
                .temperature(0.8)
                .top_k(32)
                .seed(i as u64);
            if let Some(ms) = deadline_ms {
                p = p.deadline_ms(ms);
            }
            engine.submit(p)
        })
        .collect::<mod_transformer::Result<_>>()?;

    let mut latencies = Vec::new();
    for (i, mut gen) in gens.into_iter().enumerate() {
        if i == 0 {
            // the streaming view: tokens print as each decode step lands
            print!("  request 0 streams:");
            while let Some(ev) = gen.next_event() {
                match ev {
                    Event::Token { token, .. } => {
                        print!(" {token}");
                        let _ = std::io::stdout().flush();
                    }
                    Event::Done(u) => {
                        println!(
                            "\n  request 0: {} prompt + {} generated tokens \
                             in {:.2}s (queued {:.3}s)",
                            u.prefill_tokens,
                            u.decode_tokens,
                            u.latency.as_secs_f64(),
                            u.queue_latency.as_secs_f64()
                        );
                        latencies.push(u.latency.as_secs_f64());
                    }
                    Event::Error(e) => println!("\n  request 0 failed: {e}"),
                }
            }
        } else {
            // the blocking view: wait() folds the stream into a Response
            match gen.wait() {
                Ok(resp) => latencies.push(resp.latency.as_secs_f64()),
                Err(e) => println!("  request {i} failed: {e}"),
            }
        }
    }
    latencies.sort_by(|a, b| a.total_cmp(b));

    let stats = engine.shutdown();
    println!("\n=== engine report ===");
    println!(
        "requests: {} completed on {} persistent session(s), {} admitted \
         mid-flight | throughput {:.1} tok/s",
        stats.completed, stats.sessions, stats.mid_session_admissions,
        stats.tokens_per_sec()
    );
    if !latencies.is_empty() {
        let p =
            |q: f64| latencies[((latencies.len() - 1) as f64 * q) as usize];
        println!(
            "latency p50 {:.2}s  p90 {:.2}s  p99 {:.2}s",
            p(0.5), p(0.9), p(0.99)
        );
    }
    println!(
        "MoD effect: {:.0}% of block invocations skipped, {} capacity \
         drops, {:.2e} FLOPs/token",
        100.0 * stats.skip_fraction(),
        stats.capacity_drops,
        stats.total_flops / stats.tokens_generated.max(1) as f64
    );
    Ok(())
}
