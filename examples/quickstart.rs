//! Quickstart: the whole MoD stack in ~60 lines.
//!
//! Loads the `mod_tiny` artifact bundle (built by `make artifacts`),
//! trains for a handful of steps on the synthetic corpus, evaluates under
//! the training-style top-k routing, and generates a few tokens through
//! the layer-sliced decode runtime — demonstrating that routed-around
//! blocks are *really skipped* (see the skip fraction it prints).
//!
//! Run: `cargo run --release --example quickstart`

use std::sync::Arc;

use mod_transformer::coordinator::{Trainer, TrainerOptions};
use mod_transformer::data::{BatchIter, CorpusSpec, MarkovCorpus};
use mod_transformer::runtime::{Bundle, Engine};
use mod_transformer::serve::{DecodeSession, RoutingDecision};

fn main() -> anyhow::Result<()> {
    // 1. open the artifact bundle (AOT-compiled by `make artifacts`)
    let engine = Arc::new(Engine::cpu()?);
    let bundle = Arc::new(Bundle::open(
        engine,
        std::path::Path::new("artifacts/mod_tiny"),
    )?);
    println!(
        "bundle {}: {} params, routed layers {:?}",
        bundle.manifest.name, bundle.manifest.n_params,
        bundle.manifest.routed_layers
    );

    // 2. train a few steps on the synthetic corpus
    let corpus = MarkovCorpus::new(CorpusSpec::default(), 7);
    let data = BatchIter::new(
        corpus,
        bundle.manifest.train.batch_size,
        bundle.manifest.model.seq_len,
    );
    let mut trainer = Trainer::new(bundle.clone(), data, None)?;
    let outcome = trainer.run(&TrainerOptions {
        steps: Some(20),
        log_every: 5,
        run_dir: "runs/quickstart".into(),
        ..Default::default()
    })?;
    println!(
        "trained {} steps: loss {:.3}, {:.2} steps/s",
        outcome.steps, outcome.final_loss, outcome.steps_per_sec
    );

    // 3. held-out evaluation (top-k routing, as in training)
    let eval = trainer.evaluate("topk", 2)?;
    println!(
        "eval: ce {:.3}, predictor accuracy {:.2}, participation {:.3}",
        eval.ce, eval.pred_acc, eval.participation
    );

    // 4. generate through the layer-sliced decode runtime
    let params = trainer.params()?;
    let mut session = DecodeSession::new(
        &bundle, &params, 1, RoutingDecision::RouterThreshold,
    )?;
    let mut tok = mod_transformer::data::BOS as i32;
    let mut out = Vec::new();
    for _ in 0..32 {
        let logits = session.step(&[tok], &[true])?;
        let next = logits
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1))
            .map(|(i, _)| i)
            .unwrap_or(0);
        out.push(next);
        tok = next as i32;
    }
    let report = session.report();
    println!("generated {:?}...", &out[..8.min(out.len())]);
    println!(
        "decode: {:.0} tok/s, {:.0}% of routed-block invocations skipped \
         (MoD's compute saving, measured)",
        report.tokens_per_sec(),
        100.0 * report.skip_fraction()
    );
    Ok(())
}
