"""L2 transformer substrate: RMSNorm, RoPE, attention and MLP layers.

Every compute-heavy op has two implementations selected by
`ModelConfig.use_pallas`:
  * the L1 Pallas kernels from `compile.kernels` (interpret=True), or
  * the pure-jnp oracles from `compile.kernels.ref` (XLA-fused fast path).
Both are asserted numerically identical in `python/tests/`, so either can
be baked into the AOT artifacts without changing semantics.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import kernels
from .kernels import ref
from .configs import ModelConfig


def rmsnorm(x, gain, eps: float = 1e-6):
    """Root-mean-square layer norm (no bias, no mean-centering)."""
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * gain


def rope_angles(positions, d_head: int, theta: float):
    """Rotary embedding angles for int32 positions [...]. -> [..., d_head/2]."""
    half = d_head // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    return positions.astype(jnp.float32)[..., None] * freqs


def apply_rope(x, positions, theta: float):
    """Rotate q/k by position. x: [B,H,S,Dh]; positions: [B,S] int32.

    Positions are the *original* sequence positions — essential for MoD's
    compacted blocks, where the S axis holds a gathered subset of tokens.
    """
    b, h, s, dh = x.shape
    ang = rope_angles(positions, dh, theta)  # [B,S,Dh/2]
    cos = jnp.cos(ang)[:, None, :, :]
    sin = jnp.sin(ang)[:, None, :, :]
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def attention_layer(x, layer_params, positions, cfg: ModelConfig, valid=None):
    """Pre-norm multi-head causal self-attention with RoPE.

    x: [B,S,D] (possibly a compacted [B,C,D] MoD buffer); positions: [B,S]
    original token positions; valid: optional [B,S] key-validity mask.
    Returns the attention output (no residual add — callers own residuals).
    """
    b, s, _ = x.shape
    h, dh = cfg.n_heads, cfg.d_head
    xn = rmsnorm(x, layer_params["attn_norm"])
    q = (xn @ layer_params["wq"]).reshape(b, s, h, dh).transpose(0, 2, 1, 3)
    k = (xn @ layer_params["wk"]).reshape(b, s, h, dh).transpose(0, 2, 1, 3)
    v = (xn @ layer_params["wv"]).reshape(b, s, h, dh).transpose(0, 2, 1, 3)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    if cfg.use_pallas:
        valid_arr = (jnp.ones((b, s), jnp.int32) if valid is None
                     else valid.astype(jnp.int32))
        # custom-VJP wrapper: Pallas forward, oracle-derived backward
        o = kernels.vjp.causal_attention(q, k, v, positions, positions,
                                         valid_arr)
    else:
        o = ref.causal_attention_ref(
            q, k, v, pos_q=positions, pos_k=positions, valid_k=valid
        )
    o = o.transpose(0, 2, 1, 3).reshape(b, s, h * dh)
    return o @ layer_params["wo"]


def mlp_layer(x, layer_params, cfg: ModelConfig):
    """Pre-norm dense feedforward. Returns the MLP output (no residual)."""
    xn = rmsnorm(x, layer_params["mlp_norm"])
    if cfg.use_pallas:
        return kernels.vjp.fused_mlp(xn, layer_params["w1"],
                                     layer_params["w2"])
    return ref.mlp_ref(xn, layer_params["w1"], layer_params["w2"])


def ff_apply(x, layer_params, cfg: ModelConfig):
    """Feedforward with ff-mode dispatch (dense vs MoE); no residual add.

    Used by both the MoD compact path (staged MoDE routes around blocks
    whose MLP is itself an MoE) and the masked evaluation path.
    """
    from .configs import FF_DENSE, FF_MODE_INTEGRATED

    if cfg.ff_mode == FF_DENSE:
        return mlp_layer(x, layer_params, cfg)
    from . import routing  # lazy: routing imports layers

    out, _noop = routing.moe_mlp(
        x, layer_params, cfg, integrated=cfg.ff_mode == FF_MODE_INTEGRATED
    )
    return out


def block_fn(x, layer_params, positions, cfg: ModelConfig, valid=None):
    """A full transformer block f = MLP ∘ Attn with internal residuals.

    This is the `f` of the paper's Eq. (1). For MoD-compacted inputs the
    caller applies the router-gate scaling and the outer residual; here we
    keep the standard intra-block residual wiring so a capacity-T MoD block
    is *exactly* a vanilla block.
    """
    x = x + attention_layer(x, layer_params, positions, cfg, valid=valid)
    x = x + ff_apply(x, layer_params, cfg)
    return x


def embed(tokens, params):
    """Token embedding lookup, scaled by sqrt(D) (tied-embedding convention)."""
    emb = params["embed"]
    d = emb.shape[1]
    return emb[tokens] * jnp.sqrt(jnp.asarray(d, emb.dtype))


def unembed(x, params):
    """Final norm + tied unembedding -> logits over the vocab."""
    xn = rmsnorm(x, params["final_norm"])
    return xn @ params["embed"].T
