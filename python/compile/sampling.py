"""L2 decode path: per-block single-token step functions for the L3 server.

The Rust serving runtime (rust/src/serve/) is *layer-sliced*: each transformer
block is a separate PJRT executable, and the coordinator decides per token
per routed block — using the causal predictor (paper §3.5) — whether to
invoke the block at all. A skipped block costs zero FLOPs and zero KV-cache
slots, which is how the paper's decode-time compute/memory savings become
measurable wall-clock effects on this testbed.

Artifacts produced from this module (see aot.py):
  embed_step            (tokens i32[B], embed)                  -> h f32[B,D]
  block_decode_L{len}   one per distinct KV-cache length         -> see below
  router_score_step     (h, router_w)                            -> r f32[B]
  predictor_step        (h, w1, b1, w2)                          -> logit f32[B]
  logits_head           (h, final_norm, embed)                   -> f32[B,V]

KV caches are *compacted*: a routed block's cache has only
ceil(capacity_frac * max_len) slots, with explicit per-slot original
positions + validity — the MoD memory saving the paper observes (§4.1).
Cache tensors stay on-device as PJRT buffers; only h and the routing
scalars round-trip to the coordinator.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .configs import ModelConfig
from .layers import apply_rope, rmsnorm
from .kernels.ref import NEG_INF


def embed_step_fn(cfg: ModelConfig):
    """(tokens i32[B], embed f32[V,D]) -> (h f32[B,D],)."""

    def fn(tokens, embed):
        d = embed.shape[1]
        return (embed[tokens] * jnp.sqrt(jnp.asarray(d, embed.dtype)),)

    return fn


def logits_head_fn(cfg: ModelConfig):
    """(h f32[B,D], final_norm f32[D], embed f32[V,D]) -> (logits f32[B,V],)."""

    def fn(h, final_norm, embed):
        return (rmsnorm(h, final_norm) @ embed.T,)

    return fn


def router_score_step_fn(cfg: ModelConfig):
    """(h f32[B,D], router_w f32[D]) -> (r f32[B],). Raw router weight."""

    def fn(h, router_w):
        return (h @ router_w,)

    return fn


def predictor_step_fn(cfg: ModelConfig):
    """(h, pred.w1, pred.b1, pred.w2) -> (logit f32[B],).

    sigmoid(logit) > 0.5  ⇔  "this token would be in the top-k" — the causal
    routing rule the coordinator applies.
    """

    def fn(h, w1, b1, w2):
        hid = jax.nn.relu(h @ w1 + b1)
        return (hid @ w2,)

    return fn


def block_decode_fn(cfg: ModelConfig, cache_len: int):
    """Single-token block step over a `cache_len`-slot compacted KV cache.

    Signature (B = compiled batch size, L = cache_len, KD = n_heads*d_head):
      (h f32[B,D], pos i32[B], gate f32[B], participate f32[B],
       slot i32[B],
       cache_k f32[B,L,KD], cache_v f32[B,L,KD],
       cache_pos i32[B,L], cache_valid f32[B,L],
       attn_norm, wq, wk, wv, wo, mlp_norm, w1, w2)
      -> (h' f32[B,D], cache_k', cache_v', cache_pos', cache_valid')

    Semantics per batch element b:
      * participate[b]==0 → h'[b]=h[b]; the written cache slot is marked
        invalid (the coordinator normally doesn't even call the executable
        when the whole batch skips — this mask handles mixed batches).
      * participate[b]==1 → the token's K/V (+pos, valid) are written at
        slot[b]; attention runs over valid cache slots (the just-written
        slot included, so the token attends to itself); output delta is
        scaled by gate[b] (the raw router weight, Eq. 1) and added onto h.
    """
    h_heads, dh, d = cfg.n_heads, cfg.d_head, cfg.d_model

    def write_slot(cache, value, slot):
        """vmapped dynamic_update_slice along the L axis. cache [L,...]"""
        return jax.lax.dynamic_update_slice_in_dim(
            cache, value[None], slot, axis=0
        )

    def fn(h, pos, gate, participate, slot,
           cache_k, cache_v, cache_pos, cache_valid,
           attn_norm, wq, wk, wv, wo, mlp_norm, w1, w2):
        b = h.shape[0]
        xn = rmsnorm(h, attn_norm)
        q = (xn @ wq).reshape(b, h_heads, 1, dh)
        k = (xn @ wk).reshape(b, h_heads, 1, dh)
        v = (xn @ wv).reshape(b, h_heads, 1, dh)
        pos_b = pos[:, None]  # [B,1]
        q = apply_rope(q, pos_b, cfg.rope_theta)
        k = apply_rope(k, pos_b, cfg.rope_theta)

        # Write this token's K/V into its slot (validity = participate).
        k_flat = k.transpose(0, 2, 1, 3).reshape(b, h_heads * dh)
        v_flat = v.transpose(0, 2, 1, 3).reshape(b, h_heads * dh)
        new_k = jax.vmap(write_slot)(cache_k, k_flat, slot)
        new_v = jax.vmap(write_slot)(cache_v, v_flat, slot)
        new_pos = jax.vmap(write_slot)(cache_pos, pos, slot)
        new_valid = jax.vmap(write_slot)(cache_valid, participate, slot)

        # Attend: q over all valid cache slots with pos <= current pos.
        kc = new_k.reshape(b, cache_len, h_heads, dh).transpose(0, 2, 1, 3)
        vc = new_v.reshape(b, cache_len, h_heads, dh).transpose(0, 2, 1, 3)
        scale = 1.0 / jnp.sqrt(jnp.asarray(dh, h.dtype))
        logits = jnp.einsum("bhqd,bhkd->bhqk", q, kc) * scale  # [B,H,1,L]
        ok = (new_valid > 0.5) & (new_pos <= pos[:, None])
        logits = jnp.where(ok[:, None, None, :], logits, NEG_INF)
        w = jax.nn.softmax(logits, axis=-1)
        attn = jnp.einsum("bhqk,bhkd->bhqd", w, vc)
        attn = attn.transpose(0, 2, 1, 3).reshape(b, h_heads * dh) @ wo

        h_mid = h + attn
        hn = rmsnorm(h_mid, mlp_norm)
        mlp = jax.nn.gelu(hn @ w1, approximate=True) @ w2
        delta = attn + mlp  # total block update relative to input h

        scaled = gate[:, None] * participate[:, None] * delta
        h_out = h + scaled

        # Non-participating elements must leave the cache untouched beyond
        # the invalid marker; simplest correct form: select old vs new.
        p3 = participate[:, None, None] > 0.5
        p2 = participate[:, None] > 0.5
        out_k = jnp.where(p3, new_k, cache_k)
        out_v = jnp.where(p3, new_v, cache_v)
        out_pos = jnp.where(p2, new_pos, cache_pos)
        # valid flag: write 0/1 as computed (marks slot consumed or not)
        return h_out, out_k, out_v, out_pos, new_valid

    return fn


def cache_lengths(cfg: ModelConfig, max_len: int,
                  slack: float = 1.5) -> dict[int, int]:
    """Per-layer compacted KV-cache length for a `max_len` generation.

    Routed blocks get ceil(capacity_frac * max_len * slack) slots: threshold
    routing admits ~capacity_frac of tokens in expectation (the aux BCE loss
    centres router sigmoids on 0.5), but any given sequence can run hot, so
    the cache carries `slack` headroom. If a layer's cache still fills up,
    the Rust coordinator *drops* further tokens from that block (routes them
    around it) — exactly the capacity-exceeded token-dropping semantics of
    paper §3.1. `rust/src/serve/kv_cache.rs` owns that enforcement and
    reports occupancy/drop statistics.
    """
    out = {}
    for l in range(cfg.n_layers):
        if cfg.is_routed_block(l):
            c = int(-(-cfg.capacity_frac * max_len * slack // 1))  # ceil
            out[l] = max(1, min(max_len, c))
        else:
            out[l] = max_len
    return out
