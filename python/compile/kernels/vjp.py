"""Differentiable wrappers: Pallas forward, oracle-derived backward.

Interpret-mode `pallas_call` does not support `jax.grad` end-to-end in the
pinned jax version (pl.load's abstract eval breaks under the transpose
transformation). The standard production pattern applies anyway — flash
attention et al. ship custom VJPs — so each kernel gets a `jax.custom_vjp`
whose forward runs the L1 Pallas kernel and whose backward is derived by
`jax.vjp` of the pure-jnp oracle. The two are asserted numerically equal in
python/tests/test_kernels.py, so the pairing is sound by construction.
"""

from __future__ import annotations

import jax

from . import attention as _attention
from . import mlp as _mlp
from . import mod_gather as _mod_gather
from . import router as _router
from . import ref


@jax.custom_vjp
def causal_attention(q, k, v, pos_q, pos_k, valid_k):
    return _attention.causal_attention(q, k, v, pos_q, pos_k, valid_k)


def _attn_fwd(q, k, v, pos_q, pos_k, valid_k):
    out = _attention.causal_attention(q, k, v, pos_q, pos_k, valid_k)
    return out, (q, k, v, pos_q, pos_k, valid_k)


def _attn_bwd(res, g):
    q, k, v, pos_q, pos_k, valid_k = res
    _, vjp = jax.vjp(
        lambda q_, k_, v_: ref.causal_attention_ref(
            q_, k_, v_, pos_q=pos_q, pos_k=pos_k, valid_k=valid_k
        ),
        q, k, v,
    )
    dq, dk, dv = vjp(g)
    return dq, dk, dv, None, None, None


causal_attention.defvjp(_attn_fwd, _attn_bwd)


@jax.custom_vjp
def fused_mlp(x, w1, w2):
    return _mlp.fused_mlp(x, w1, w2)


def _mlp_fwd(x, w1, w2):
    return _mlp.fused_mlp(x, w1, w2), (x, w1, w2)


def _mlp_bwd(res, g):
    x, w1, w2 = res
    _, vjp = jax.vjp(ref.mlp_ref, x, w1, w2)
    return vjp(g)


fused_mlp.defvjp(_mlp_fwd, _mlp_bwd)


@jax.custom_vjp
def router_scores(x, w_r):
    return _router.router_scores(x, w_r)


def _router_fwd(x, w_r):
    return _router.router_scores(x, w_r), (x, w_r)


def _router_bwd(res, g):
    x, w_r = res
    _, vjp = jax.vjp(ref.router_scores_ref, x, w_r)
    return vjp(g)


router_scores.defvjp(_router_fwd, _router_bwd)


@jax.custom_vjp
def gather_tokens(x, idx):
    return _mod_gather.gather_tokens(x, idx)


def _gather_fwd(x, idx):
    return _mod_gather.gather_tokens(x, idx), (x, idx)


def _gather_bwd(res, g):
    x, idx = res
    _, vjp = jax.vjp(lambda x_: ref.gather_tokens_ref(x_, idx), x)
    (dx,) = vjp(g)
    return dx, None


gather_tokens.defvjp(_gather_fwd, _gather_bwd)


@jax.custom_vjp
def scatter_add_weighted(x, updates, idx, gates):
    return _mod_gather.scatter_add_weighted(x, updates, idx, gates)


def _scatter_fwd(x, updates, idx, gates):
    out = _mod_gather.scatter_add_weighted(x, updates, idx, gates)
    return out, (x, updates, idx, gates)


def _scatter_bwd(res, g):
    x, updates, idx, gates = res
    _, vjp = jax.vjp(
        lambda x_, u_, g_: ref.scatter_add_weighted_ref(x_, u_, idx, g_),
        x, updates, gates,
    )
    dx, du, dg = vjp(g)
    return dx, du, None, dg


scatter_add_weighted.defvjp(_scatter_fwd, _scatter_bwd)
