"""L1 Pallas kernel: fused position-wise feedforward (gelu(x@W1)@W2).

Tiling (DESIGN.md §3 hardware adaptation): the grid runs over row blocks of
the flattened token axis; each program keeps one [BM, D] activation tile, the
[D, F] / [F, D] weight panels, and the [BM, F] hidden tile in VMEM, so the
intermediate activation never round-trips to HBM — this is the fusion the
paper's TPU stack gets from XLA, expressed explicitly as one kernel.

VMEM at default tiles (BM=128, D=512, F=2048, f32):
  x 256 KiB + w1 4 MiB + h 1 MiB + w2 4 MiB + out 256 KiB ≈ 9.5 MiB — fits
  the ~16 MiB envelope; larger F must shrink BM or panel F (documented in
  EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_M = 128


def _mlp_kernel(x_ref, w1_ref, w2_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)
    h = jax.nn.gelu(x @ w1_ref[...].astype(jnp.float32), approximate=True)
    o_ref[...] = (h @ w2_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_m", "interpret"))
def fused_mlp(x, w1, w2, *, block_m: int = DEFAULT_BLOCK_M,
              interpret: bool = True):
    """Pallas fused MLP matching `ref.mlp_ref`. x: [..., D] -> [..., D]."""
    orig_shape = x.shape
    d = orig_shape[-1]
    f = w1.shape[1]
    xm = x.reshape(-1, d)
    m = xm.shape[0]
    bm = min(block_m, m)
    # Pad rows to a multiple of the block so the grid is exact.
    pad = (-m) % bm
    if pad:
        xm = jnp.concatenate([xm, jnp.zeros((pad, d), xm.dtype)], axis=0)
    grid = (xm.shape[0] // bm,)
    out = pl.pallas_call(
        _mlp_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, d), lambda i: (i, 0)),
            pl.BlockSpec((d, f), lambda i: (0, 0)),
            pl.BlockSpec((f, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((xm.shape[0], d), x.dtype),
        interpret=interpret,
    )(xm, w1, w2)
    if pad:
        out = out[:m]
    return out.reshape(orig_shape)
