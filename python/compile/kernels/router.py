"""L1 Pallas kernel: MoD router scoring r_i = w_r . x_i (paper §3.4).

A deliberately thin matvec kernel: the router is a single linear projection
to a scalar per token. Its cost is negligible next to the block it gates
(D MACs/token vs ~12·D² MACs/token), but keeping it as an explicit kernel
lets the scoring run fused over the token tile while the activations are
already VMEM-resident, and gives the L3 decode server a single artifact for
routing decisions.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_S = 256


def _router_kernel(x_ref, w_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)  # [bs, D]
    w = w_ref[...].astype(jnp.float32)  # [D]
    o_ref[...] = (x @ w).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def router_scores(x, w_r, *, block_s: int = DEFAULT_BLOCK_S,
                  interpret: bool = True):
    """Pallas router scoring matching `ref.router_scores_ref`.

    x: [B,S,D]; w_r: [D] -> scores [B,S].
    """
    b, s, d = x.shape
    xm = x.reshape(b * s, d)
    m = xm.shape[0]
    bs = min(block_s, m)
    pad = (-m) % bs
    if pad:
        xm = jnp.concatenate([xm, jnp.zeros((pad, d), xm.dtype)], axis=0)
    out = pl.pallas_call(
        _router_kernel,
        grid=(xm.shape[0] // bs,),
        in_specs=[
            pl.BlockSpec((bs, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bs,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((xm.shape[0],), x.dtype),
        interpret=interpret,
    )(xm, w_r)
    if pad:
        out = out[:m]
    return out.reshape(b, s)
