"""L1 Pallas kernels (interpret=True on CPU) + pure-jnp oracles.

Import surface used by the L2 model (`compile.layers`):
  causal_attention, fused_mlp, gather_tokens, scatter_add_weighted,
  router_scores — each has a `*_ref` oracle in `ref.py`.
"""

from .attention import causal_attention
from .mlp import fused_mlp
from .mod_gather import gather_tokens, scatter_add_weighted
from .router import router_scores
from . import ref
from . import vjp

__all__ = [
    "causal_attention",
    "fused_mlp",
    "gather_tokens",
    "scatter_add_weighted",
    "router_scores",
    "ref",
]
