"""L1 Pallas kernel: flash-style causal attention, tiled for TPU VMEM.

Hardware adaptation (DESIGN.md §3): the paper's testbed batches full-capacity
attention on TPU MXUs. We express the HBM↔VMEM schedule with a `BlockSpec`
grid over (batch*heads, query blocks); each program streams KV blocks through
VMEM scratch while maintaining the online-softmax running max/denominator —
the TPU analogue of the warp-level tiling a CUDA flash kernel would use.

Runs under `interpret=True` only (the CPU PJRT plugin cannot execute Mosaic
custom-calls); structure — not interpret wallclock — is what matters here.
VMEM budget at default tiles (BQ=BK=128, Dh≤128, f32):
  q tile 128*128*4 = 64 KiB, k/v tiles 64 KiB each, logits 128*128*4 = 64 KiB,
  accumulator + stats < 70 KiB  →  ≈ 320 KiB/program, far inside the ~16 MiB
  VMEM envelope, leaving headroom for 8-deep double buffering.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import NEG_INF

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128


def _attn_kernel(pos_q_ref, pos_k_ref, valid_k_ref, q_ref, k_ref, v_ref, o_ref,
                 *, block_k: int, sk: int, scale: float):
    """One (batch*head, q-block) program: online softmax over KV blocks."""
    bq, dh = q_ref.shape
    q = q_ref[...].astype(jnp.float32) * scale
    pos_q = pos_q_ref[...]  # [bq] int32 original positions

    m = jnp.full((bq,), NEG_INF, jnp.float32)  # running max
    l = jnp.zeros((bq,), jnp.float32)  # running denominator
    acc = jnp.zeros((bq, dh), jnp.float32)

    num_kb = pl.cdiv(sk, block_k)

    def body(kb, carry):
        m, l, acc = carry
        k_blk = pl.load(k_ref, (pl.ds(kb * block_k, block_k), slice(None)))
        v_blk = pl.load(v_ref, (pl.ds(kb * block_k, block_k), slice(None)))
        pos_k = pl.load(pos_k_ref, (pl.ds(kb * block_k, block_k),))
        valid = pl.load(valid_k_ref, (pl.ds(kb * block_k, block_k),))
        logits = q @ k_blk.astype(jnp.float32).T  # [bq, block_k]
        # Ragged tail: the last KV block may read past sk (interpret mode
        # clamps, duplicating the final key) — mask those lanes explicitly.
        kidx = kb * block_k + jax.lax.iota(jnp.int32, block_k)
        in_bounds = kidx < sk
        mask = ((pos_k[None, :] <= pos_q[:, None])
                & (valid[None, :] > 0) & in_bounds[None, :])
        # OOB v rows are NaN-padded in interpret mode; their softmax weight
        # is 0 but 0*NaN = NaN in p @ v — zero them explicitly.
        v_blk = jnp.where(in_bounds[:, None], v_blk.astype(jnp.float32), 0.0)
        logits = jnp.where(mask, logits, NEG_INF)
        m_new = jnp.maximum(m, logits.max(axis=-1))
        correction = jnp.exp(m - m_new)
        p = jnp.exp(logits - m_new[:, None])
        l_new = l * correction + p.sum(axis=-1)
        acc_new = acc * correction[:, None] + p @ v_blk.astype(jnp.float32)
        return m_new, l_new, acc_new

    m, l, acc = jax.lax.fori_loop(0, num_kb, body, (m, l, acc))
    # Guard fully-masked rows (no valid keys yet): emit zeros, not NaNs.
    l_safe = jnp.where(l > 0.0, l, 1.0)
    o_ref[...] = (acc / l_safe[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_q", "block_k", "interpret")
)
def causal_attention(q, k, v, pos_q=None, pos_k=None, valid_k=None, *,
                     block_q: int = DEFAULT_BLOCK_Q,
                     block_k: int = DEFAULT_BLOCK_K,
                     interpret: bool = True):
    """Pallas causal attention matching `ref.causal_attention_ref`.

    q: [B,H,Sq,Dh]; k, v: [B,H,Sk,Dh]; optional original-position tensors
    pos_q [B,Sq] / pos_k [B,Sk] and key validity valid_k [B,Sk].
    """
    b, h, sq, dh = q.shape
    sk = k.shape[2]
    if pos_q is None:
        pos_q = jnp.broadcast_to(jnp.arange(sq, dtype=jnp.int32)[None], (b, sq))
    if pos_k is None:
        pos_k = jnp.broadcast_to(jnp.arange(sk, dtype=jnp.int32)[None], (b, sk))
    if valid_k is None:
        valid_k = jnp.ones((b, sk), jnp.int32)
    else:
        valid_k = valid_k.astype(jnp.int32)

    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    # Merge batch and head axes into the grid's leading dimension.
    qm = q.reshape(b * h, sq, dh)
    km = k.reshape(b * h, sk, dh)
    vm = v.reshape(b * h, sk, dh)
    pos_qm = jnp.repeat(pos_q, h, axis=0)  # [B*H, Sq]
    pos_km = jnp.repeat(pos_k, h, axis=0)
    valid_m = jnp.repeat(valid_k, h, axis=0)

    grid = (b * h, pl.cdiv(sq, block_q))
    kernel = functools.partial(
        _attn_kernel, block_k=block_k, sk=sk,
        scale=float(1.0 / (dh ** 0.5)),
    )
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, block_q), lambda g, i: (g, i)),      # pos_q
            pl.BlockSpec((None, sk), lambda g, i: (g, 0)),           # pos_k
            pl.BlockSpec((None, sk), lambda g, i: (g, 0)),           # valid_k
            pl.BlockSpec((None, block_q, dh), lambda g, i: (g, i, 0)),  # q
            pl.BlockSpec((None, sk, dh), lambda g, i: (g, 0, 0)),    # k
            pl.BlockSpec((None, sk, dh), lambda g, i: (g, 0, 0)),    # v
        ],
        out_specs=pl.BlockSpec((None, block_q, dh), lambda g, i: (g, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sq, dh), q.dtype),
        interpret=interpret,
    )(pos_qm, pos_km, valid_m, qm, km, vm)
    return out.reshape(b, h, sq, dh)
