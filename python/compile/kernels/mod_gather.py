"""L1 Pallas kernels for the MoD routing data movement (paper §3.4, Eq. 1).

Two kernels implement the capacity-compaction that gives MoD its FLOP
savings:

  * `gather_tokens`  — pack the top-k selected token embeddings [B,S,D] into
    the capacity-sized buffer [B,C,D] the block actually computes on.
  * `scatter_add_weighted` — the residual write-back: routed tokens receive
    `gate * block_out` added onto their residual stream; bypassed tokens are
    untouched.

Hardware adaptation: on TPU this is the dynamic-slice-friendly layout —
each grid program owns one sequence row in VMEM and walks the capacity
indices with dynamic loads/stores; a GPU implementation of the paper would
instead do warp-level compaction. The index walk is a fori_loop of
`pl.dynamic`-indexed row copies, which Mosaic maps onto VMEM
gather/scatter; D stays the contiguous minor axis for lane efficiency.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gather_kernel(idx_ref, x_ref, o_ref):
    """One program per batch row: o[c] = x[idx[c]] for c in [0, C)."""
    c = idx_ref.shape[0]

    def body(j, _):
        src = idx_ref[j]
        row = pl.load(x_ref, (pl.ds(src, 1), slice(None)))
        pl.store(o_ref, (pl.ds(j, 1), slice(None)), row)
        return 0

    jax.lax.fori_loop(0, c, body, 0)


@functools.partial(jax.jit, static_argnames=("interpret",))
def gather_tokens(x, idx, *, interpret: bool = True):
    """Pallas gather matching `ref.gather_tokens_ref`.

    x: [B,S,D]; idx: [B,C] int32 -> [B,C,D].
    """
    b, s, d = x.shape
    c = idx.shape[1]
    return pl.pallas_call(
        _gather_kernel,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((None, c), lambda i: (i, 0)),
            pl.BlockSpec((None, s, d), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, c, d), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, c, d), x.dtype),
        interpret=interpret,
    )(idx, x)


def _scatter_kernel(idx_ref, gates_ref, x_ref, upd_ref, o_ref):
    """One program per batch row: o = x; o[idx[c]] += gates[c] * upd[c]."""
    c = idx_ref.shape[0]
    o_ref[...] = x_ref[...]

    def body(j, _):
        dst = idx_ref[j]
        g = gates_ref[j].astype(jnp.float32)
        upd = pl.load(upd_ref, (pl.ds(j, 1), slice(None))).astype(jnp.float32)
        cur = pl.load(o_ref, (pl.ds(dst, 1), slice(None))).astype(jnp.float32)
        pl.store(o_ref, (pl.ds(dst, 1), slice(None)),
                 (cur + g * upd).astype(o_ref.dtype))
        return 0

    jax.lax.fori_loop(0, c, body, 0)


@functools.partial(jax.jit, static_argnames=("interpret",))
def scatter_add_weighted(x, updates, idx, gates, *, interpret: bool = True):
    """Pallas residual scatter matching `ref.scatter_add_weighted_ref`.

    x: [B,S,D]; updates: [B,C,D]; idx: [B,C] int32 (unique per row);
    gates: [B,C]. Rows of `idx` must be unique (expert-choice top-k
    guarantees this) — the += walk is sequential per row, so even duplicate
    indices would accumulate deterministically.
    """
    b, s, d = x.shape
    c = idx.shape[1]
    return pl.pallas_call(
        _scatter_kernel,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((None, c), lambda i: (i, 0)),
            pl.BlockSpec((None, c), lambda i: (i, 0)),
            pl.BlockSpec((None, s, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((None, c, d), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, s, d), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, s, d), x.dtype),
        interpret=interpret,
    )(idx, gates, x, updates)
