"""Pure-jnp oracles for every Pallas kernel in this package.

These are the single source of numerical truth: the Pallas kernels in
`attention.py`, `mlp.py`, `mod_gather.py` and `router.py` are asserted
allclose against these in `python/tests/test_kernels.py` (hypothesis sweeps
over shapes and dtypes), and the L2 model uses exactly these functions when
`ModelConfig.use_pallas` is False — so a kernel bug can never silently
diverge from the reference semantics.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30  # additive-mask value; finite to stay NaN-free under f32/bf16


def causal_attention_ref(q, k, v, *, pos_q=None, pos_k=None, valid_k=None):
    """Multi-head scaled-dot-product attention with a causal mask.

    q: [B, H, Sq, Dh], k/v: [B, H, Sk, Dh].
    pos_q/pos_k: optional [B, Sq]/[B, Sk] int32 original positions — used by
      the MoD compact path where the Sq/Sk axes hold a *gathered subset* of
      the sequence; causality must be judged on original positions.
    valid_k: optional [B, Sk] bool — False keys are masked out (padded slots,
      KV-cache slots beyond the write head, tokens routed around the block).
    """
    b, h, sq, dh = q.shape
    sk = k.shape[2]
    if pos_q is None:
        pos_q = jnp.broadcast_to(jnp.arange(sq, dtype=jnp.int32)[None], (b, sq))
    if pos_k is None:
        pos_k = jnp.broadcast_to(jnp.arange(sk, dtype=jnp.int32)[None], (b, sk))
    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, q.dtype))
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, k) * scale
    mask = pos_k[:, None, None, :] <= pos_q[:, None, :, None]  # [B,1,Sq,Sk]
    if valid_k is not None:
        mask = mask & valid_k[:, None, None, :]
    logits = jnp.where(mask, logits, NEG_INF)
    # Rows with no valid key (possible for padded queries) softmax over the
    # NEG_INF plateau to a uniform distribution; callers mask those outputs.
    weights = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", weights, v)


def mlp_ref(x, w1, w2):
    """Position-wise feedforward: gelu(x @ w1) @ w2. x: [..., D]."""
    return jax.nn.gelu(x @ w1, approximate=True) @ w2


def router_scores_ref(x, w_r):
    """Scalar router weight per token: r_i = w_r . x_i. x: [B,S,D], w_r: [D]."""
    return jnp.einsum("bsd,d->bs", x, w_r)


def gather_tokens_ref(x, idx):
    """Compact selected tokens: x [B,S,D], idx [B,C] int32 -> [B,C,D]."""
    return jnp.take_along_axis(x, idx[:, :, None], axis=1)


def scatter_add_weighted_ref(x, updates, idx, gates):
    """Residual scatter of Eq. (1): out = x, out[idx] += gate * updates.

    x: [B,S,D]; updates: [B,C,D]; idx: [B,C] int32 (unique per row);
    gates: [B,C]. Matches the paper: only routed tokens receive the
    gated block output; bypassed tokens pass through unchanged.
    """
    b, s, _ = x.shape
    weighted = updates * gates[:, :, None]
    onehot = (idx[:, :, None] == jnp.arange(s, dtype=idx.dtype)[None, None, :])
    return x + jnp.einsum("bcs,bcd->bsd", onehot.astype(x.dtype), weighted)


def topk_mask_ref(scores, k):
    """Expert-choice selection: per-row top-k of `scores` [B,S].

    Returns (idx [B,k] int32 sorted ascending, mask [B,S] bool).
    Sorting ascending keeps the compacted sub-sequence in original temporal
    order so the compact attention's causal mask stays a simple pos compare.
    Stable argsort breaks ties toward earlier positions, keeping the
    selection deterministic across backends.
    """
    b, s = scores.shape
    # Selection is non-differentiable (integer indices); stop_gradient also
    # sidesteps sort_key_val's VJP, which needs a batched-gather feature the
    # pinned xla_client lacks. Gradients reach the scores via the gate
    # multiply and the aux BCE loss, exactly as in the paper.
    order = jnp.argsort(-jax.lax.stop_gradient(scores), axis=-1, stable=True)
    idx = jnp.sort(order[:, :k].astype(jnp.int32), axis=-1)
    mask = jnp.zeros((b, s), bool).at[jnp.arange(b)[:, None], idx].set(True)
    return idx, mask


def mod_block_ref(x, idx, gates, block_fn):
    """Full MoD routed-block semantics (paper Eq. 1), reference composition.

    x: [B,S,D]; idx: [B,C] (ascending original positions of the top-k);
    gates: [B,C] router weights of the selected tokens; block_fn maps
    ([B,C,D], pos [B,C]) -> [B,C,D] (self-attention + MLP over the
    compacted tokens, causal in original positions).
    """
    xc = gather_tokens_ref(x, idx)
    out = block_fn(xc, idx)
    return scatter_add_weighted_ref(x, out, idx, gates)
