"""MODCKPT1 — the tiny tensor-bundle format shared with the Rust side.

Layout (little-endian):
  magic    8 bytes  b"MODCKPT1"
  count    u32      number of tensors
  per tensor:
    name_len u32, name utf-8 bytes
    dtype    u8   (0 = f32, 1 = i32)
    ndim     u8
    dims     u32 * ndim
    data     raw LE bytes (product(dims) * itemsize)

Mirrored by `rust/src/coordinator/checkpoint.rs`; both sides round-trip in
tests. Used for initial parameters (written by aot.py), training
checkpoints, and exported router-decision dumps.
"""

from __future__ import annotations

import struct

import numpy as np

MAGIC = b"MODCKPT1"
_DTYPES = {0: np.float32, 1: np.int32}
_CODES = {np.dtype(np.float32): 0, np.dtype(np.int32): 1}


def save(path: str, tensors: dict[str, np.ndarray]) -> None:
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<I", len(tensors)))
        for name, arr in tensors.items():
            arr = np.ascontiguousarray(arr)
            code = _CODES[arr.dtype]
            nb = name.encode()
            f.write(struct.pack("<I", len(nb)))
            f.write(nb)
            f.write(struct.pack("<BB", code, arr.ndim))
            for dim in arr.shape:
                f.write(struct.pack("<I", dim))
            f.write(arr.tobytes())


def load(path: str) -> dict[str, np.ndarray]:
    with open(path, "rb") as f:
        if f.read(8) != MAGIC:
            raise ValueError(f"{path}: bad magic (not a MODCKPT1 file)")
        (count,) = struct.unpack("<I", f.read(4))
        out: dict[str, np.ndarray] = {}
        for _ in range(count):
            (nlen,) = struct.unpack("<I", f.read(4))
            name = f.read(nlen).decode()
            code, ndim = struct.unpack("<BB", f.read(2))
            dims = struct.unpack(f"<{ndim}I", f.read(4 * ndim)) if ndim else ()
            dt = np.dtype(_DTYPES[code])
            n = int(np.prod(dims)) if dims else 1
            data = f.read(n * dt.itemsize)
            out[name] = np.frombuffer(data, dt).reshape(dims).copy()
        return out
