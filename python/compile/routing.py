"""L2 MoD routing machinery (paper §3.2–3.5).

Implements:
  * expert-choice top-k selection over scalar router weights (§3.3),
  * the compact gather → block → gated scatter path of Eq. (1) (§3.4),
  * the auxiliary BCE loss that centres router sigmoids on 0.5 (§3.5,
    sampling method 1),
  * the causal top-k-membership predictor (§3.5, sampling method 2),
  * the stochastic-routing control (Gaussian router weights, §3.3 / fig 3),
  * a masked (non-compacted) block used for predictor-based evaluation,
    numerically equivalent to skip semantics at decode time.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from . import kernels
from .kernels import ref
from .configs import ModelConfig, ROUTING_STOCHASTIC


def compute_router_scores(x, w_r, cfg: ModelConfig):
    """Raw router weights r_i = w_r . x_i  ([B,S,D],[D] -> [B,S])."""
    if cfg.use_pallas:
        return kernels.vjp.router_scores(x, w_r)
    return ref.router_scores_ref(x, w_r)


def select_topk(scores, capacity: int):
    """Expert-choice selection: (idx [B,C] ascending, mask [B,S] bool)."""
    return ref.topk_mask_ref(scores, capacity)


def stochastic_scores(shape, key):
    """Control router: weights ~ N(0,1), independent of content (fig 3)."""
    return jax.random.normal(key, shape)


def mod_block_compact(x, layer_params, cfg: ModelConfig, scores):
    """The trained-model MoD path: Eq. (1) with real capacity compaction.

    x: [B,S,D]; scores: [B,S] router weights for this block. Returns
    (x_next, topk_mask). The block computes on only C = capacity tokens —
    this is where the FLOP savings physically live.
    """
    from .layers import block_fn

    b, s, _ = x.shape
    c = cfg.capacity(s)
    idx, mask = select_topk(scores, c)
    gates = jnp.take_along_axis(scores, idx, axis=1)  # selected raw weights
    if cfg.use_pallas:
        xc = kernels.vjp.gather_tokens(x, idx)
    else:
        xc = ref.gather_tokens_ref(x, idx)
    # f over the compacted tokens; causality judged on original positions.
    out = block_fn(xc, layer_params, idx, cfg)
    # Paper: multiply f's output by the router weight so the router sits on
    # the gradient path; bypassing tokens keep the bare residual. Eq. (1)
    # writes r*f(X̃)+x for selected tokens — block_fn already includes the
    # internal residual x̃, so scatter adds gate*(out − x̃) + x̃ ... the paper
    # gates the whole block output; we follow the paper exactly:
    # x_next[i] = gate_i * f(x̃)_i + x_i, implemented as x += gate*f_out with
    # f_out the *delta* form. To keep gradients shaped as published we gate
    # the block's residual-inclusive output delta:
    delta = out - xc
    if cfg.use_pallas:
        x_next = kernels.vjp.scatter_add_weighted(x, delta, idx, gates)
    else:
        x_next = ref.scatter_add_weighted_ref(x, delta, idx, gates)
    return x_next, mask


def mod_block_masked(x, layer_params, cfg: ModelConfig, route_mask):
    """Skip-semantics MoD block without compaction (predictor-based eval).

    route_mask: [B,S] bool — True tokens participate; False tokens pass the
    residual through unchanged AND are excluded from the block's keys/values
    (exactly the semantics the L3 decode server realizes by not invoking the
    block executable). FLOP cost here is full-size — this path exists for
    *evaluation parity*, not savings; savings are measured in the Rust
    decode runtime and accounted analytically in `rust/src/flops/`.

    Gate: sigmoid(router score) is NOT applied here; the caller supplies the
    gate values it wants via `gates` multiplication outside if needed. For
    predictor-routed evaluation we follow the paper and use the raw router
    weight of each participating token.
    """
    from .layers import attention_layer, ff_apply

    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    attn = attention_layer(x, layer_params, positions, cfg, valid=route_mask)
    h = x + jnp.where(route_mask[:, :, None], attn, 0.0)
    mlp = ff_apply(h, layer_params, cfg)
    out = h + jnp.where(route_mask[:, :, None], mlp, 0.0)
    return out


def routed_block_apply(x, layer_params, cfg: ModelConfig, *, scores=None,
                       route_mask=None, gate_scores=None):
    """Unified entry: compact path when scores given, masked path otherwise.

    Masked path applies Eq. (1)'s gating explicitly:
      x_next = mask * (gate * (f(x) - x) ) + x
    with f evaluated under key-masking.
    """
    if scores is not None:
        return mod_block_compact(x, layer_params, cfg, scores)
    assert route_mask is not None
    out = mod_block_masked(x, layer_params, cfg, route_mask)
    if gate_scores is not None:
        delta = out - x
        out = x + jnp.where(
            route_mask[:, :, None], gate_scores[:, :, None] * delta, 0.0
        )
    return out, route_mask


# ---------------------------------------------------------------------------
# Sampling helpers (§3.5)
# ---------------------------------------------------------------------------

def router_aux_bce(scores, topk_mask):
    """Method 1: BCE(router logits, stop_grad(top-k membership)).

    Centres sigmoid(score) around 0.5: selected tokens are pushed above,
    non-selected below — making `sigmoid(score) > 0.5` a causal routing
    rule at sampling time.
    """
    targets = jax.lax.stop_gradient(topk_mask.astype(scores.dtype))
    logp = jax.nn.log_sigmoid(scores)
    lognp = jax.nn.log_sigmoid(-scores)
    return -jnp.mean(targets * logp + (1.0 - targets) * lognp)


def predictor_logits(x, pred_params):
    """Method 2: small MLP predicting top-k membership from stop_grad(x).

    x: [B,S,D] -> logits [B,S]. The stop-gradient keeps the predictor from
    shaping the trunk representation (paper: "receives the same inputs ...
    with a stop gradient").
    """
    xs = jax.lax.stop_gradient(x)
    h = jax.nn.relu(xs @ pred_params["w1"] + pred_params["b1"])
    return jnp.einsum("bsh,h->bs", h, pred_params["w2"])


def predictor_bce(logits, topk_mask):
    """BCE loss + accuracy for the membership predictor."""
    targets = jax.lax.stop_gradient(topk_mask.astype(logits.dtype))
    logp = jax.nn.log_sigmoid(logits)
    lognp = jax.nn.log_sigmoid(-logits)
    loss = -jnp.mean(targets * logp + (1.0 - targets) * lognp)
    acc = jnp.mean(((logits > 0.0) == topk_mask).astype(jnp.float32))
    return loss, acc


# ---------------------------------------------------------------------------
# Mixture-of-Experts / MoDE feedforward (§4.3, fig 7)
# ---------------------------------------------------------------------------

def moe_mlp(x, layer_params, cfg: ModelConfig, *, integrated: bool):
    """Expert-choice MoE MLP; with `integrated`, expert 0 is a no-op.

    x: [B,S,D]. Each (real) expert e selects its own top-C_e tokens from a
    per-expert router column (expert-choice, perfect load balance), applies
    its MLP, and scatters back gated by the router weight — the same Eq. (1)
    machinery as MoD, vectorized over experts. With `integrated` (MoDE-
    integrated), an extra no-op column competes for tokens: tokens it wins
    are *explicitly* routed to the residual path, which the paper found
    clearly better than capacity-starving real experts.

    Returns (mlp_out, noop_mask or None): mlp_out excludes the residual
    (caller adds x + out), noop_mask [B,S] marks tokens won by the no-op.
    """
    from .layers import rmsnorm

    b, s, d = x.shape
    n_e = cfg.n_experts
    w_router = layer_params["moe_router"]  # [D, n_e (+1 if integrated)]
    xn = rmsnorm(x, layer_params["mlp_norm"])
    scores = jnp.einsum("bsd,de->bse", xn, w_router)  # [B,S,E(+1)]
    c_e = max(1, int(round(cfg.expert_capacity_frac * s)))

    out = jnp.zeros_like(x)
    for e in range(n_e):
        col = e + 1 if integrated else e
        idx, _ = ref.topk_mask_ref(scores[:, :, col], c_e)
        gates = jnp.take_along_axis(scores[:, :, col], idx, axis=1)
        gates = jax.nn.sigmoid(gates)
        xc = ref.gather_tokens_ref(xn, idx)
        w1 = layer_params["moe_w1"][e]
        w2 = layer_params["moe_w2"][e]
        yc = ref.mlp_ref(xc, w1, w2)
        out = ref.scatter_add_weighted_ref(out, yc, idx, gates)

    noop_mask = None
    if integrated:
        # Tokens whose argmax column is the no-op expert: counted for
        # analysis; they simply receive no expert update (residual path).
        noop_mask = jnp.argmax(scores, axis=-1) == 0
    return out, noop_mask
