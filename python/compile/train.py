"""L2 training step: loss, AdamW + cosine schedule, one-executable step.

The whole step — forward, total loss (CE + aux BCE + predictor BCE),
backward, gradient clip, AdamW update with warmup+cosine LR — lowers into a
single HLO executable that the Rust trainer invokes per batch. Parameter /
optimizer-state tensors cross the boundary as flat ordered lists (see
`model.param_names`).

Metrics tensor layout (f32[8], `METRIC_NAMES`): the Rust side indexes this
by position, so the order is part of the artifact ABI.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from .configs import ModelConfig, TrainConfig, ROUTING_STOCHASTIC
from . import model, routing

METRIC_NAMES = (
    "loss",          # 0: total optimized loss
    "ce",            # 1: next-token cross entropy (the paper's objective)
    "aux_bce",       # 2: router aux BCE (sec 3.5 method 1)
    "pred_bce",      # 3: predictor BCE (sec 3.5 method 2)
    "pred_acc",      # 4: predictor top-k membership accuracy
    "router_frac",   # 5: fraction of router sigmoids > 0.5 (fig 5 histogram)
    "grad_norm",     # 6: pre-clip global grad norm
    "lr",            # 7: learning rate this step
)


def cross_entropy(logits, tokens):
    """Next-token CE in nats/token; predicts tokens[:,1:] from logits[:,:-1]."""
    logp = jax.nn.log_softmax(logits[:, :-1].astype(jnp.float32), axis=-1)
    tgt = tokens[:, 1:]
    nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


def total_loss(params, tokens, cfg: ModelConfig, rng=None):
    """CE + aux losses. Returns (loss, metrics dict)."""
    logits, aux = model.forward(params, tokens, cfg, rng=rng,
                                routing_mode="topk")
    ce = cross_entropy(logits, tokens)
    loss = ce

    aux_bce = jnp.zeros((), jnp.float32)
    pred_bce = jnp.zeros((), jnp.float32)
    pred_acc = jnp.zeros((), jnp.float32)
    router_frac = jnp.zeros((), jnp.float32)
    routed = sorted(aux["topk_masks"].keys())
    if routed and cfg.routing != ROUTING_STOCHASTIC:
        for l in routed:
            scores = aux["router_scores"][l]
            mask = aux["topk_masks"][l]
            aux_bce = aux_bce + routing.router_aux_bce(scores, mask)
            router_frac = router_frac + jnp.mean(
                (scores > 0.0).astype(jnp.float32)
            )
            if l in aux["pred_logits"]:
                pb, pa = routing.predictor_bce(aux["pred_logits"][l], mask)
                pred_bce = pred_bce + pb
                pred_acc = pred_acc + pa
        n = float(len(routed))
        aux_bce, router_frac = aux_bce / n, router_frac / n
        if aux["pred_logits"]:
            m = float(len(aux["pred_logits"]))
            pred_bce, pred_acc = pred_bce / m, pred_acc / m
        loss = loss + cfg.aux_loss_weight * aux_bce + pred_bce

    metrics = {
        "loss": loss, "ce": ce, "aux_bce": aux_bce, "pred_bce": pred_bce,
        "pred_acc": pred_acc, "router_frac": router_frac,
    }
    return loss, metrics


def lr_schedule(step, tc: TrainConfig):
    """Linear warmup → cosine decay to min_lr_frac over total_steps."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, (step + 1.0) / max(1, tc.warmup_steps))
    t = jnp.clip((step - tc.warmup_steps)
                 / max(1, tc.total_steps - tc.warmup_steps), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
    frac = tc.min_lr_frac + (1.0 - tc.min_lr_frac) * cos
    return tc.learning_rate * warm * frac


def _is_decayed(name: str) -> bool:
    """Weight decay applies to matrices, not norms/biases/routers."""
    return not (
        name.endswith("_norm") or name.endswith(".b1")
        or name.endswith("router_w")
    )


def adamw_update(cfg: ModelConfig, tc: TrainConfig, params, grads, m, v, step):
    """One AdamW step; returns (params', m', v', lr, grad_norm)."""
    names = model.param_names(cfg)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(grads[n])) for n in names))
    clip = jnp.minimum(1.0, tc.grad_clip / (gnorm + 1e-9))
    lr = lr_schedule(step, tc)
    t = step.astype(jnp.float32) + 1.0
    bc1 = 1.0 - tc.beta1 ** t
    bc2 = 1.0 - tc.beta2 ** t
    new_p, new_m, new_v = {}, {}, {}
    for n in names:
        g = grads[n] * clip
        m_n = tc.beta1 * m[n] + (1.0 - tc.beta1) * g
        v_n = tc.beta2 * v[n] + (1.0 - tc.beta2) * jnp.square(g)
        upd = (m_n / bc1) / (jnp.sqrt(v_n / bc2) + tc.eps)
        p = params[n]
        if _is_decayed(n):
            upd = upd + tc.weight_decay * p
        new_p[n] = p - lr * upd
        new_m[n], new_v[n] = m_n, v_n
    return new_p, new_m, new_v, lr, gnorm


def train_step_fn(cfg: ModelConfig, tc: TrainConfig):
    """Build the flat-signature train step for AOT lowering.

    Signature (all leading lists flattened in `model.param_names` order):
      (tokens i32[B,S], step i32[], seed i32[], *params, *m, *v)
        -> (metrics f32[8], *params', *m', *v')
    `seed` feeds the stochastic-routing control; ignored otherwise.
    """
    names = model.param_names(cfg)
    n = len(names)

    def step_fn(tokens, step, seed, *flat):
        params = dict(zip(names, flat[:n]))
        m = dict(zip(names, flat[n:2 * n]))
        v = dict(zip(names, flat[2 * n:3 * n]))
        rng = jax.random.PRNGKey(0)
        if cfg.routing == ROUTING_STOCHASTIC:
            rng = jax.random.fold_in(jax.random.PRNGKey(17), seed)

        def loss_fn(p):
            return total_loss(p, tokens, cfg, rng=rng)

        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True
        )(params)
        new_p, new_m, new_v, lr, gnorm = adamw_update(
            cfg, tc, params, grads, m, v, step
        )
        mvec = jnp.stack([
            metrics["loss"], metrics["ce"], metrics["aux_bce"],
            metrics["pred_bce"], metrics["pred_acc"],
            metrics["router_frac"], gnorm, lr,
        ]).astype(jnp.float32)
        # Anchor `seed` into the graph even when routing is deterministic:
        # jax.jit prunes unused args at lowering, which would change the
        # executable's arity per config and break the fixed Rust-side ABI.
        mvec = mvec + seed.astype(jnp.float32) * 0.0
        outs = [mvec]
        outs += [new_p[k] for k in names]
        outs += [new_m[k] for k in names]
        outs += [new_v[k] for k in names]
        return tuple(outs)

    return step_fn


def eval_step_fn(cfg: ModelConfig, routing_mode: str = "topk"):
    """Held-out evaluation: (tokens, *params) -> metrics f32[4].

    metrics = [ce, pred_acc, router_frac, participation] where
    participation is the mean fraction of tokens actually routed *through*
    routed blocks under the given routing_mode (fig 6 FLOP accounting).
    """
    names = model.param_names(cfg)

    def fn(tokens, *flat):
        params = dict(zip(names, flat))
        logits, aux = model.forward(
            params, tokens, cfg,
            rng=jax.random.PRNGKey(0), routing_mode=routing_mode,
        )
        ce = cross_entropy(logits, tokens)
        # Anchor every param into the graph (stochastic routing never reads
        # router_w; arg pruning at lowering would break the fixed ABI).
        ce = ce + sum(jnp.sum(p) for p in flat) * 0.0
        pred_acc = jnp.zeros((), jnp.float32)
        frac = jnp.zeros((), jnp.float32)
        part = jnp.zeros((), jnp.float32)
        routed = sorted(aux["topk_masks"].keys())
        if routed:
            for l in routed:
                mask = aux["topk_masks"][l]
                part = part + jnp.mean(mask.astype(jnp.float32))
                frac = frac + jnp.mean(
                    (aux["router_scores"][l] > 0.0).astype(jnp.float32)
                )
                if l in aux["pred_logits"]:
                    # accuracy of predictor vs the mode's own mask
                    _, pa = routing.predictor_bce(aux["pred_logits"][l], mask)
                    pred_acc = pred_acc + pa
            nl = float(len(routed))
            part, frac = part / nl, frac / nl
            if aux["pred_logits"]:
                pred_acc = pred_acc / float(len(aux["pred_logits"]))
        return (jnp.stack([ce, pred_acc, frac, part]).astype(jnp.float32),)

    return fn


def init_opt_state(cfg: ModelConfig, params) -> tuple[dict, dict]:
    """Zero-initialized AdamW first/second moments."""
    zeros = {n: jnp.zeros_like(params[n]) for n in model.param_names(cfg)}
    return zeros, {n: jnp.zeros_like(v) for n, v in zeros.items()}
