"""Model / training configuration shared by the JAX build path.

The Rust side mirrors these fields in `rust/src/config/` (TOML). The AOT
pipeline (`aot.py`) serializes the resolved config into the artifact
manifest so the coordinator can verify it is driving the executables it
thinks it is.
"""

from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass
from typing import Any


# Routing modes (mirrors rust/src/config/mod.rs::RoutingMode)
ROUTING_NONE = "none"  # vanilla transformer: every token through every block
ROUTING_MOD_EVERY = "mod_every"  # MoD routing on every block
ROUTING_MOD_INTERLEAVED = "mod_interleaved"  # MoD on odd blocks (paper's best)
ROUTING_STOCHASTIC = "stochastic"  # control: gaussian router weights (fig 3)

# Feedforward modes
FF_DENSE = "dense"
FF_MOE = "moe"  # expert-choice MoE MLP
FF_MODE_INTEGRATED = "mode_integrated"  # MoE with a no-op expert (fig 7)

ROUTING_MODES = (
    ROUTING_NONE,
    ROUTING_MOD_EVERY,
    ROUTING_MOD_INTERLEAVED,
    ROUTING_STOCHASTIC,
)
FF_MODES = (FF_DENSE, FF_MOE, FF_MODE_INTEGRATED)


@dataclass(frozen=True)
class ModelConfig:
    """Hyperparameters of one transformer variant.

    Defaults give a tiny CPU-trainable model; the isoFLOP ladders in
    `rust/src/config/presets.rs` scale these up/down.
    """

    vocab_size: int = 259  # 256 bytes + BOS/EOS/PAD
    d_model: int = 128
    n_layers: int = 4
    n_heads: int = 4
    d_head: int = 32
    d_ff: int = 512
    seq_len: int = 256

    # --- Mixture-of-Depths ---
    routing: str = ROUTING_NONE
    # Fraction of the sequence admitted to a routed block (paper's best: 0.125).
    capacity_frac: float = 0.125
    # Auxiliary BCE loss weight pushing router sigmoid to straddle 0.5 (sec 3.5).
    aux_loss_weight: float = 0.01
    # Train the causal top-k membership predictor (second sampling method).
    train_predictor: bool = True
    predictor_hidden: int = 64

    # --- Mixture-of-Experts / MoDE (fig 7) ---
    ff_mode: str = FF_DENSE
    n_experts: int = 4
    # staged MoDE = routing != none AND ff_mode == moe (MoD wraps the block,
    # the block's MLP is an MoE). integrated MoDE = ff_mode == mode_integrated.
    expert_capacity_frac: float = 0.25

    # --- numerics ---
    rope_theta: float = 10000.0
    use_pallas: bool = False  # lower L1 pallas kernels into the HLO (interpret)

    def __post_init__(self) -> None:
        if self.routing not in ROUTING_MODES:
            raise ValueError(f"bad routing mode {self.routing!r}")
        if self.ff_mode not in FF_MODES:
            raise ValueError(f"bad ff mode {self.ff_mode!r}")
        if self.d_model != self.n_heads * self.d_head:
            raise ValueError(
                f"d_model ({self.d_model}) must equal n_heads*d_head "
                f"({self.n_heads}*{self.d_head})"
            )
        if not (0.0 < self.capacity_frac <= 1.0):
            raise ValueError(f"capacity_frac out of (0,1]: {self.capacity_frac}")

    # ---- derived quantities ----
    def capacity(self, seq_len: int | None = None) -> int:
        """Tokens admitted to a routed block (the paper's k / C). At least 1."""
        s = self.seq_len if seq_len is None else seq_len
        return max(1, int(round(self.capacity_frac * s)))

    def is_routed_block(self, layer: int) -> bool:
        """Whether block `layer` (0-based) has MoD routing applied.

        Interleaved routing puts MoD on odd blocks so that block 0 — which
        consumes raw embeddings — always runs at full capacity, matching the
        paper's "every other block" setup.
        """
        if self.routing in (ROUTING_NONE,):
            return False
        if self.routing == ROUTING_MOD_INTERLEAVED:
            return layer % 2 == 1
        return True  # mod_every / stochastic

    def routed_layers(self) -> list[int]:
        return [l for l in range(self.n_layers) if self.is_routed_block(l)]

    def n_params(self) -> int:
        """Exact parameter count (matches init_params; embeddings tied)."""
        d, h, f, v = self.d_model, self.n_heads * self.d_head, self.d_ff, self.vocab_size
        per_layer = 4 * d * h  # wq wk wv wo
        if self.ff_mode == FF_DENSE:
            per_layer += 2 * d * f
        else:
            n_e = self.n_experts
            per_layer += n_e * 2 * d * f  # expert banks
            per_layer += d * (n_e + (1 if self.ff_mode == FF_MODE_INTEGRATED else 0))
        per_layer += 2 * d  # two rmsnorm gains
        total = self.n_layers * per_layer
        total += v * d  # tied embedding/unembedding
        total += d  # final norm
        for l in range(self.n_layers):
            if self.is_routed_block(l):
                total += d  # router projection
                if self.train_predictor:
                    # pred.w1 [d,h] + pred.b1 [h] + pred.w2 [h]
                    total += d * self.predictor_hidden + 2 * self.predictor_hidden
        return total

    def to_json(self) -> dict[str, Any]:
        return dataclasses.asdict(self)

    @staticmethod
    def from_json(d: dict[str, Any]) -> "ModelConfig":
        return ModelConfig(**d)


@dataclass(frozen=True)
class TrainConfig:
    """Optimizer / schedule hyperparameters baked into the train_step HLO."""

    batch_size: int = 8
    learning_rate: float = 3e-3
    min_lr_frac: float = 0.1
    warmup_steps: int = 50
    total_steps: int = 500  # cosine period == total steps (paper sec 3.6)
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-9
    grad_clip: float = 1.0

    def to_json(self) -> dict[str, Any]:
        return dataclasses.asdict(self)

    @staticmethod
    def from_json(d: dict[str, Any]) -> "TrainConfig":
        return TrainConfig(**d)


def config_fingerprint(mc: ModelConfig, tc: TrainConfig | None = None) -> str:
    """Stable content hash used by `make artifacts` incrementality."""
    import hashlib

    blob = json.dumps(
        {"model": mc.to_json(), "train": tc.to_json() if tc else None},
        sort_keys=True,
    )
    return hashlib.sha256(blob.encode()).hexdigest()[:16]
