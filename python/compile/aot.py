"""AOT pipeline: lower the L2 model to HLO-text artifacts + manifest.

This is the only place Python touches the system: `make artifacts` runs it
once per model config; the Rust coordinator then drives the resulting
executables with zero Python on any request path.

Interchange format is HLO *text*, NOT `lowered.compile()`/`.serialize()`:
jax >= 0.5 emits HloModuleProtos with 64-bit instruction ids which the
`xla` crate's xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the
text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).

Artifact bundle layout (one directory per config under artifacts/):
  manifest.json            ABI: config, param specs, metric names, files
  init.ckpt                seeded initial params + Adam moments (MODCKPT1)
  train_step.hlo.txt       (tokens, step, seed, *p, *m, *v) -> (metrics, ...)
  eval_topk.hlo.txt        held-out eval under training-style top-k routing
  eval_predictor.hlo.txt   eval under causal predictor routing (fig 6)
  eval_router.hlo.txt      eval under causal aux-BCE router routing (fig 6)
  embed_step.hlo.txt       decode: token -> h                (per batch size)
  block_decode_B{b}_L{l}.hlo.txt   decode block per (batch, cache len)
  router_score_B{b}.hlo.txt / predictor_B{b}.hlo.txt / logits_head_B{b}.hlo.txt
"""

from __future__ import annotations

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from .configs import ModelConfig, TrainConfig, config_fingerprint
from . import ckpt, model, sampling, train

FF_DENSE = "dense"


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_fn(fn, example_args) -> str:
    return to_hlo_text(jax.jit(fn).lower(*example_args))


def spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


# ---------------------------------------------------------------------------
# Presets (mirrored by rust/src/config/presets.rs)
# ---------------------------------------------------------------------------

def preset(name: str) -> tuple[ModelConfig, TrainConfig]:
    tiny_train = TrainConfig(batch_size=8, total_steps=400)
    base = dict(vocab_size=259, d_model=128, n_layers=4, n_heads=4,
                d_head=32, d_ff=512, seq_len=256)
    presets: dict[str, ModelConfig] = {
        "baseline_tiny": ModelConfig(**base, routing="none"),
        "mod_tiny": ModelConfig(**base, routing="mod_interleaved",
                                capacity_frac=0.125),
        "mod_tiny_every": ModelConfig(**base, routing="mod_every",
                                      capacity_frac=0.125),
        "mod_tiny_stochastic": ModelConfig(**base, routing="stochastic",
                                           capacity_frac=0.125,
                                           train_predictor=False),
        "moe_tiny": ModelConfig(**{**base, "d_ff": 256}, ff_mode="moe",
                                n_experts=4),
        "mode_staged_tiny": ModelConfig(**{**base, "d_ff": 256},
                                        routing="mod_interleaved",
                                        capacity_frac=0.125, ff_mode="moe",
                                        n_experts=4),
        "mode_integrated_tiny": ModelConfig(**{**base, "d_ff": 256},
                                            ff_mode="mode_integrated",
                                            n_experts=4),
        "kernel_demo": ModelConfig(vocab_size=259, d_model=64, n_layers=2,
                                   n_heads=2, d_head=32, d_ff=128,
                                   seq_len=128, routing="mod_interleaved",
                                   capacity_frac=0.25, use_pallas=True),
    }
    if name not in presets:
        raise SystemExit(
            f"unknown preset {name!r}; have {sorted(presets)}"
        )
    return presets[name], tiny_train


# ---------------------------------------------------------------------------
# Bundle builder
# ---------------------------------------------------------------------------

def build_bundle(out_dir: str, name: str, mc: ModelConfig, tc: TrainConfig,
                 *, seed: int = 0, decode_batches=(1, 4),
                 max_decode_len: int = 256, force: bool = False,
                 with_decode: bool = True, with_train: bool = True) -> str:
    bundle = os.path.join(out_dir, name)
    manifest_path = os.path.join(bundle, "manifest.json")
    fp = config_fingerprint(mc, tc)
    stamp = {
        "fingerprint": fp, "seed": seed,
        "decode_batches": list(decode_batches),
        "max_decode_len": max_decode_len,
        "with_decode": with_decode, "with_train": with_train,
    }
    if not force and os.path.exists(manifest_path):
        try:
            old = json.load(open(manifest_path))
            if all(old.get(k) == v for k, v in stamp.items()):
                print(f"[aot] {name}: up to date ({fp})")
                return bundle
        except (json.JSONDecodeError, OSError):
            pass
    os.makedirs(bundle, exist_ok=True)
    print(f"[aot] {name}: building (fingerprint {fp})")

    names = model.param_names(mc)
    specs = model.param_specs(mc)
    b, s = tc.batch_size, mc.seq_len
    artifacts: dict[str, object] = {}

    # --- initial params + Adam state ---
    params = model.init_params(mc, jax.random.PRNGKey(seed))
    tensors = {n: np.asarray(params[n]) for n in names}
    ckpt.save(os.path.join(bundle, "init.ckpt"), tensors)
    artifacts["init"] = "init.ckpt"

    p_specs = [spec(shape) for _, shape in specs]

    if with_train:
        # --- train step ---
        fn = train.train_step_fn(mc, tc)
        args = [spec((b, s), jnp.int32), spec((), jnp.int32),
                spec((), jnp.int32)] + p_specs * 3
        text = lower_fn(fn, args)
        with open(os.path.join(bundle, "train_step.hlo.txt"), "w") as f:
            f.write(text)
        artifacts["train_step"] = "train_step.hlo.txt"
        print(f"[aot]   train_step: {len(text) / 1e6:.1f} MB hlo text")

        # --- eval variants ---
        eval_modes = ["topk"]
        if mc.routing in ("mod_every", "mod_interleaved"):
            eval_modes += ["router"]
            if mc.train_predictor:
                eval_modes += ["predictor"]
        for mode in eval_modes:
            fn = train.eval_step_fn(mc, routing_mode=mode)
            text = lower_fn(fn, [spec((b, s), jnp.int32)] + p_specs)
            fname = f"eval_{mode}.hlo.txt"
            with open(os.path.join(bundle, fname), "w") as f:
                f.write(text)
            artifacts[f"eval_{mode}"] = fname

    # --- decode path (dense-ff configs only) ---
    cache_lens = sampling.cache_lengths(mc, max_decode_len)
    if with_decode and mc.ff_mode == FF_DENSE:
        d, v = mc.d_model, mc.vocab_size
        kd = mc.n_heads * mc.d_head
        dec: dict[str, object] = {}
        for db in decode_batches:
            text = lower_fn(sampling.embed_step_fn(mc),
                            [spec((db,), jnp.int32), spec((v, d))])
            fname = f"embed_step_B{db}.hlo.txt"
            open(os.path.join(bundle, fname), "w").write(text)
            dec[f"embed_B{db}"] = fname

            text = lower_fn(sampling.logits_head_fn(mc),
                            [spec((db, d)), spec((d,)), spec((v, d))])
            fname = f"logits_head_B{db}.hlo.txt"
            open(os.path.join(bundle, fname), "w").write(text)
            dec[f"logits_B{db}"] = fname

            if any(mc.is_routed_block(l) for l in range(mc.n_layers)):
                text = lower_fn(sampling.router_score_step_fn(mc),
                                [spec((db, d)), spec((d,))])
                fname = f"router_score_B{db}.hlo.txt"
                open(os.path.join(bundle, fname), "w").write(text)
                dec[f"router_B{db}"] = fname
                if mc.train_predictor:
                    text = lower_fn(
                        sampling.predictor_step_fn(mc),
                        [spec((db, d)), spec((d, mc.predictor_hidden)),
                         spec((mc.predictor_hidden,)),
                         spec((mc.predictor_hidden,))])
                    fname = f"predictor_B{db}.hlo.txt"
                    open(os.path.join(bundle, fname), "w").write(text)
                    dec[f"predictor_B{db}"] = fname

            for cl in sorted(set(cache_lens.values())):
                fn = sampling.block_decode_fn(mc, cl)
                args = [
                    spec((db, d)), spec((db,), jnp.int32), spec((db,)),
                    spec((db,)), spec((db,), jnp.int32),
                    spec((db, cl, kd)), spec((db, cl, kd)),
                    spec((db, cl), jnp.int32), spec((db, cl)),
                    spec((d,)), spec((d, kd)), spec((d, kd)), spec((d, kd)),
                    spec((kd, d)), spec((d,)), spec((d, mc.d_ff)),
                    spec((mc.d_ff, d)),
                ]
                text = lower_fn(fn, args)
                fname = f"block_decode_B{db}_L{cl}.hlo.txt"
                open(os.path.join(bundle, fname), "w").write(text)
                dec[f"block_B{db}_L{cl}"] = fname
        artifacts["decode"] = dec

    manifest = {
        **stamp,
        "name": name,
        "model": mc.to_json(),
        "train": tc.to_json(),
        "params": [
            {"name": n, "shape": list(shape), "dtype": "f32"}
            for n, shape in specs
        ],
        "metrics": list(train.METRIC_NAMES),
        "eval_metrics": ["ce", "pred_acc", "router_frac", "participation"],
        "cache_lengths": {str(l): cl for l, cl in cache_lens.items()},
        "routed_layers": mc.routed_layers(),
        "n_params": mc.n_params(),
        "artifacts": artifacts,
    }
    with open(manifest_path, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] {name}: done")
    return bundle


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--preset", action="append", default=[],
                    help="named preset bundle(s) to build")
    ap.add_argument("--default-set", action="store_true",
                    help="build the bundles the examples/tests expect")
    ap.add_argument("--model-json", help="inline ModelConfig JSON")
    ap.add_argument("--train-json", help="inline TrainConfig JSON")
    ap.add_argument("--name", help="bundle name for --model-json")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--decode-batches", default="1,4")
    ap.add_argument("--max-decode-len", type=int, default=256)
    ap.add_argument("--no-decode", action="store_true")
    ap.add_argument("--no-train", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args(argv)

    decode_batches = tuple(
        int(x) for x in args.decode_batches.split(",") if x
    )
    todo: list[tuple[str, ModelConfig, TrainConfig]] = []
    presets = list(args.preset)
    if args.default_set:
        presets += ["baseline_tiny", "mod_tiny", "kernel_demo"]
    for p in presets:
        mc, tc = preset(p)
        todo.append((p, mc, tc))
    if args.model_json:
        if not args.name:
            raise SystemExit("--model-json requires --name")
        mc = ModelConfig.from_json(json.loads(args.model_json))
        tc = (TrainConfig.from_json(json.loads(args.train_json))
              if args.train_json else TrainConfig())
        todo.append((args.name, mc, tc))
    if not todo:
        raise SystemExit("nothing to build: pass --preset/--default-set/"
                         "--model-json")

    for name, mc, tc in todo:
        build_bundle(
            args.out_dir, name, mc, tc, seed=args.seed,
            decode_batches=decode_batches,
            max_decode_len=args.max_decode_len, force=args.force,
            with_decode=not args.no_decode, with_train=not args.no_train,
        )


if __name__ == "__main__":
    main()
