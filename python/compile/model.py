"""L2 model: the Mixture-of-Depths transformer and all paper variants.

One `forward` covers every configuration in the paper's evaluation:
  * vanilla baseline                     (routing="none", ff="dense")
  * MoD, every block                     (routing="mod_every")
  * MoD, every other block (paper best)  (routing="mod_interleaved")
  * stochastic-routing control (fig 3)   (routing="stochastic")
  * expert-choice MoE baseline (fig 7)   (ff="moe")
  * staged MoDE (fig 7)                  (routing=mod_*, ff="moe")
  * integrated MoDE (fig 7)              (routing="none", ff="mode_integrated")

Parameters are a flat {name: array} dict with a deterministic ordering
(`param_names`) — the same ordering the AOT manifest records and the Rust
coordinator threads through the train_step executable.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from .configs import (
    FF_DENSE,
    FF_MODE_INTEGRATED,
    ModelConfig,
    ROUTING_STOCHASTIC,
)
from . import layers, routing


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------

def param_specs(cfg: ModelConfig) -> list[tuple[str, tuple[int, ...]]]:
    """Deterministic (name, shape) list — the AOT/manifest ordering."""
    d, dh, h, f, v = (cfg.d_model, cfg.d_head, cfg.n_heads, cfg.d_ff,
                      cfg.vocab_size)
    specs: list[tuple[str, tuple[int, ...]]] = [("embed", (v, d))]
    for l in range(cfg.n_layers):
        p = f"layer_{l:02d}."
        specs += [
            (p + "attn_norm", (d,)),
            (p + "wq", (d, h * dh)),
            (p + "wk", (d, h * dh)),
            (p + "wv", (d, h * dh)),
            (p + "wo", (h * dh, d)),
            (p + "mlp_norm", (d,)),
        ]
        if cfg.ff_mode == FF_DENSE:
            specs += [(p + "w1", (d, f)), (p + "w2", (f, d))]
        else:
            cols = cfg.n_experts + (1 if cfg.ff_mode == FF_MODE_INTEGRATED else 0)
            specs += [
                (p + "moe_router", (d, cols)),
                (p + "moe_w1", (cfg.n_experts, d, f)),
                (p + "moe_w2", (cfg.n_experts, f, d)),
            ]
        if cfg.is_routed_block(l):
            specs += [(p + "router_w", (d,))]
            if cfg.train_predictor:
                specs += [
                    (p + "pred.w1", (d, cfg.predictor_hidden)),
                    (p + "pred.b1", (cfg.predictor_hidden,)),
                    (p + "pred.w2", (cfg.predictor_hidden,)),
                ]
    specs += [("final_norm", (d,))]
    return specs


def param_names(cfg: ModelConfig) -> list[str]:
    return [n for n, _ in param_specs(cfg)]


def init_params(cfg: ModelConfig, key) -> dict[str, jax.Array]:
    """Scaled-normal init; norm gains 1, biases 0, routers near-0."""
    params: dict[str, jax.Array] = {}
    for name, shape in param_specs(cfg):
        key, sub = jax.random.split(key)
        if name.endswith("_norm"):
            params[name] = jnp.ones(shape, jnp.float32)
        elif name.endswith(".b1"):
            params[name] = jnp.zeros(shape, jnp.float32)
        elif name.endswith("router_w") or name.endswith("moe_router"):
            # small init: routing starts near-uniform, gates near 0
            params[name] = 0.02 * jax.random.normal(sub, shape, jnp.float32)
        else:
            fan_in = shape[0] if len(shape) == 1 else shape[-2]
            std = 1.0 / jnp.sqrt(jnp.asarray(max(1, fan_in), jnp.float32))
            params[name] = std * jax.random.normal(sub, shape, jnp.float32)
    # deeper nets: scale output projections down by sqrt(2L)
    scale = 1.0 / jnp.sqrt(jnp.asarray(2.0 * cfg.n_layers, jnp.float32))
    for l in range(cfg.n_layers):
        p = f"layer_{l:02d}."
        params[p + "wo"] = params[p + "wo"] * scale
        if cfg.ff_mode == FF_DENSE:
            params[p + "w2"] = params[p + "w2"] * scale
        else:
            params[p + "moe_w2"] = params[p + "moe_w2"] * scale
    return params


def layer_view(params: dict[str, Any], l: int) -> dict[str, Any]:
    """Sub-dict view of one layer's tensors with the prefix stripped."""
    p = f"layer_{l:02d}."
    out = {k[len(p):]: v for k, v in params.items() if k.startswith(p)}
    pred = {k[len("pred."):]: v for k, v in out.items() if k.startswith("pred.")}
    if pred:
        out["pred"] = pred
    return out


def flatten_params(cfg: ModelConfig, params: dict[str, Any]) -> list[jax.Array]:
    return [params[n] for n in param_names(cfg)]


def unflatten_params(cfg: ModelConfig, flat) -> dict[str, Any]:
    return dict(zip(param_names(cfg), flat))


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------

def full_block(x, lp, positions, cfg: ModelConfig, aux, l):
    """Full-capacity block with ff-mode dispatch (dense / MoE / integrated)."""
    x = x + layers.attention_layer(x, lp, positions, cfg)
    if cfg.ff_mode == FF_DENSE:
        return x + layers.mlp_layer(x, lp, cfg)
    out, noop = routing.moe_mlp(
        x, lp, cfg, integrated=cfg.ff_mode == FF_MODE_INTEGRATED
    )
    if noop is not None:
        aux["noop_masks"][l] = noop
    return x + out


# ---------------------------------------------------------------------------
# Forward pass
# ---------------------------------------------------------------------------

def forward(params: dict[str, Any], tokens, cfg: ModelConfig, *,
            rng=None, routing_mode: str = "topk"):
    """Run the model. tokens: [B,S] int32.

    routing_mode:
      "topk"      — training-time expert-choice top-k (non-causal), with
                    real capacity compaction (the FLOP-saving path).
      "predictor" — causal: route where sigmoid(predictor logit) > 0.5 (the
                    paper's autoregressive sampling scheme; masked blocks).
      "router"    — causal: route where sigmoid(router score) > 0.5 (the
                    aux-BCE sampling scheme).

    Returns (logits [B,S,V], aux dict) with per-routed-block entries:
      aux["topk_masks"][l]      participation mask actually used
      aux["router_scores"][l]   raw router weights
      aux["pred_logits"][l]     predictor logits (if cfg.train_predictor)
      aux["noop_masks"][l]      integrated-MoDE no-op winners (full blocks)
    """
    b, s = tokens.shape
    x = layers.embed(tokens, params)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

    aux: dict[str, dict[int, jax.Array]] = {
        "topk_masks": {}, "router_scores": {}, "pred_logits": {},
        "noop_masks": {},
    }

    for l in range(cfg.n_layers):
        lp = layer_view(params, l)
        if not cfg.is_routed_block(l):
            x = full_block(x, lp, positions, cfg, aux, l)
            continue

        if cfg.routing == ROUTING_STOCHASTIC:
            assert rng is not None, "stochastic routing needs an rng"
            rng, sub = jax.random.split(rng)
            scores = routing.stochastic_scores((b, s), sub)
        else:
            scores = routing.compute_router_scores(x, lp["router_w"], cfg)
        aux["router_scores"][l] = scores
        if cfg.train_predictor and "pred" in lp:
            aux["pred_logits"][l] = routing.predictor_logits(x, lp["pred"])

        if routing_mode == "topk":
            x, mask = routing.mod_block_compact(x, lp, cfg, scores)
        else:
            gate_src = (aux["pred_logits"][l] if routing_mode == "predictor"
                        else scores)
            mask = gate_src > 0.0  # sigmoid(.) > 0.5
            x, _ = routing.routed_block_apply(
                x, lp, cfg, route_mask=mask, gate_scores=scores
            )
        aux["topk_masks"][l] = mask

    logits = layers.unembed(x, params)
    return logits, aux
