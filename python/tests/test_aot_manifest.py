"""AOT bundle ABI checks (pure JSON/file checks — no jax tracing).

Validates the artifact bundles `make artifacts` produced: manifest
structure, param-spec consistency with the config, cache-length rules,
artifact files present, and init checkpoint completeness. These are the
same invariants the Rust `Bundle::open` enforces — tested here so a broken
build fails in pytest before any Rust runs.
"""

import json
import os

import pytest

from compile import ckpt
from compile.configs import ModelConfig
from compile.model import param_specs

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")

BUNDLES = ["baseline_tiny", "mod_tiny", "kernel_demo"]


def bundle_dir(name):
    d = os.path.join(ARTIFACTS, name)
    if not os.path.exists(os.path.join(d, "manifest.json")):
        pytest.skip(f"bundle {name} not built (run `make artifacts`)")
    return d


@pytest.mark.parametrize("name", BUNDLES)
def test_manifest_parses_and_matches_config(name):
    d = bundle_dir(name)
    m = json.load(open(os.path.join(d, "manifest.json")))
    cfg = ModelConfig.from_json(m["model"])
    # param specs match a freshly computed ABI
    fresh = param_specs(cfg)
    assert [p["name"] for p in m["params"]] == [n for n, _ in fresh]
    assert [tuple(p["shape"]) for p in m["params"]] == [s for _, s in fresh]
    assert m["n_params"] == cfg.n_params()
    assert m["metrics"][0] == "loss"


@pytest.mark.parametrize("name", BUNDLES)
def test_artifact_files_exist(name):
    d = bundle_dir(name)
    m = json.load(open(os.path.join(d, "manifest.json")))

    def walk(node):
        if isinstance(node, str):
            assert os.path.exists(os.path.join(d, node)), node
        elif isinstance(node, dict):
            for v in node.values():
                walk(v)

    walk(m["artifacts"])


@pytest.mark.parametrize("name", BUNDLES)
def test_init_ckpt_complete(name):
    d = bundle_dir(name)
    m = json.load(open(os.path.join(d, "manifest.json")))
    tensors = ckpt.load(os.path.join(d, "init.ckpt"))
    for p in m["params"]:
        assert p["name"] in tensors, p["name"]
        assert list(tensors[p["name"]].shape) == p["shape"]


@pytest.mark.parametrize("name", BUNDLES)
def test_cache_lengths_follow_routing(name):
    d = bundle_dir(name)
    m = json.load(open(os.path.join(d, "manifest.json")))
    cfg = ModelConfig.from_json(m["model"])
    max_len = m["max_decode_len"]
    for l_str, cl in m["cache_lengths"].items():
        layer = int(l_str)
        if cfg.is_routed_block(layer):
            assert cl <= max_len
            if cfg.capacity_frac < 0.5:
                assert cl < max_len, f"routed layer {layer} not compacted"
        else:
            assert cl == max_len


def test_hlo_text_is_parseable_header():
    d = bundle_dir("mod_tiny")
    text = open(os.path.join(d, "train_step.hlo.txt")).read(200)
    assert text.startswith("HloModule"), "artifact is not HLO text"
