"""Training-step + decode-path tests: optimization, schedules, and the
decode-vs-forward parity invariant the Rust server depends on."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.configs import ModelConfig, TrainConfig
from compile import ckpt, model, sampling, train

jax.config.update("jax_platform_name", "cpu")

MICRO = dict(vocab_size=37, d_model=32, n_layers=4, n_heads=2, d_head=16,
             d_ff=64, seq_len=32)


def mk(key=0, **kw):
    cfg = ModelConfig(**MICRO, **kw)
    params = model.init_params(cfg, jax.random.PRNGKey(key))
    return cfg, params


def run_steps(cfg, params, tc, n_steps, key=1):
    fn = jax.jit(train.train_step_fn(cfg, tc))
    flat = model.flatten_params(cfg, params)
    m, v = train.init_opt_state(cfg, params)
    state = flat + model.flatten_params(cfg, m) + model.flatten_params(cfg, v)
    metrics = []
    for s in range(n_steps):
        t = jax.random.randint(jax.random.fold_in(jax.random.PRNGKey(key), s),
                               (tc.batch_size, cfg.seq_len), 0, cfg.vocab_size)
        outs = fn(t, jnp.int32(s), jnp.int32(s), *state)
        metrics.append(np.asarray(outs[0]))
        state = list(outs[1:])
    n = len(flat)
    return np.stack(metrics), model.unflatten_params(cfg, state[:n])


@pytest.mark.parametrize("kw", [
    dict(routing="none"),
    dict(routing="mod_interleaved", capacity_frac=0.25),
    dict(ff_mode="moe", n_experts=2),
], ids=["vanilla", "mod", "moe"])
def test_loss_decreases(kw):
    cfg, params = mk(**kw)
    tc = TrainConfig(batch_size=4, total_steps=30, learning_rate=1e-3)
    mets, _ = run_steps(cfg, params, tc, 30)
    # random tokens: CE should fall from ~log(V) toward the unigram floor
    assert mets[-1, 1] < mets[0, 1] - 0.05, mets[:, 1]
    assert np.all(np.isfinite(mets))


def test_metric_layout_stable():
    assert train.METRIC_NAMES == (
        "loss", "ce", "aux_bce", "pred_bce", "pred_acc", "router_frac",
        "grad_norm", "lr",
    )


def test_lr_schedule_shape():
    tc = TrainConfig(warmup_steps=10, total_steps=100, learning_rate=1.0,
                     min_lr_frac=0.1)
    lrs = [float(train.lr_schedule(jnp.int32(s), tc)) for s in range(100)]
    assert lrs[0] < lrs[9] <= 1.0 + 1e-6        # warmup ascends
    assert abs(lrs[10] - 1.0) < 0.05            # peak after warmup
    assert lrs[99] < 0.2                         # decayed near min
    assert lrs[99] >= 0.1 * 0.99                 # not below min_lr_frac


def test_weight_decay_mask():
    assert train._is_decayed("layer_00.wq")
    assert train._is_decayed("embed")
    assert not train._is_decayed("layer_00.attn_norm")
    assert not train._is_decayed("layer_01.router_w")
    assert not train._is_decayed("layer_01.pred.b1")


def test_router_learns_bce_calibration():
    """The aux BCE drives the router-sigmoid fraction above 0.5 from its
    ~0.5 init *toward* capacity_frac (the fig 5 histogram property), and
    predictor accuracy climbs. Full convergence to the capacity split
    takes more optimization than a unit test affords (EXPERIMENTS.md fig 5
    notes the same at smoke scale), so we assert clear directional motion
    plus high predictor accuracy."""
    cfg, params = mk(routing="mod_every", capacity_frac=0.25,
                     aux_loss_weight=1.0)
    tc = TrainConfig(batch_size=4, total_steps=60, learning_rate=3e-3)
    mets, _ = run_steps(cfg, params, tc, 60)
    start_frac = mets[:5, 5].mean()
    router_frac = mets[-5:, 5].mean()
    assert 0.4 < start_frac < 0.6, start_frac  # ~uniform at init
    assert router_frac < 0.40, router_frac  # moved well toward 0.25
    pred_acc = mets[-5:, 4].mean()
    assert pred_acc > 0.8, pred_acc


def test_eval_step_modes():
    cfg, params = mk(routing="mod_interleaved", capacity_frac=0.25)
    flat = model.flatten_params(cfg, params)
    t = jax.random.randint(jax.random.PRNGKey(5), (2, cfg.seq_len), 0,
                           cfg.vocab_size)
    for mode in ("topk", "router", "predictor"):
        fn = jax.jit(train.eval_step_fn(cfg, routing_mode=mode))
        (m,) = fn(t, *flat)
        m = np.asarray(m)
        assert m.shape == (4,)
        assert np.isfinite(m).all()
        assert 0.0 <= m[3] <= 1.0  # participation fraction
    # top-k mode participation is exactly the capacity fraction
    fn = jax.jit(train.eval_step_fn(cfg, routing_mode="topk"))
    (m,) = fn(t, *flat)
    np.testing.assert_allclose(m[3], cfg.capacity() / cfg.seq_len, atol=1e-6)


# ---------------------------------------------------------------------------
# decode path
# ---------------------------------------------------------------------------

def decode_sequence(cfg, params, toks, cache_len=None):
    """Pure-python reference of the Rust decode loop (layer-sliced)."""
    S = toks.shape[1]
    B = toks.shape[0]
    assert B == 1
    kd = cfg.n_heads * cfg.d_head
    cls = {l: (cache_len or S) for l in range(cfg.n_layers)}
    embed_fn = sampling.embed_step_fn(cfg)
    logits_fn = sampling.logits_head_fn(cfg)
    router_fn = sampling.router_score_step_fn(cfg)
    blocks = {L: sampling.block_decode_fn(cfg, L) for L in set(cls.values())}
    caches = {l: [jnp.zeros((B, cls[l], kd)), jnp.zeros((B, cls[l], kd)),
                  jnp.zeros((B, cls[l]), jnp.int32), jnp.zeros((B, cls[l]))]
              for l in range(cfg.n_layers)}
    slots = {l: 0 for l in range(cfg.n_layers)}
    out = []
    drops = 0
    for t in range(S):
        (h,) = embed_fn(toks[:, t], params["embed"])
        for l in range(cfg.n_layers):
            lp = model.layer_view(params, l)
            if cfg.is_routed_block(l):
                (r,) = router_fn(h, lp["router_w"])
                part, gate = bool(r[0] > 0), r
            else:
                part, gate = True, jnp.ones((B,))
            if not part:
                continue
            if slots[l] >= cls[l]:  # capacity-exceeded drop (paper 3.1)
                drops += 1
                continue
            ck, cv, cp, cval = caches[l]
            h, ck, cv, cp, cval = blocks[cls[l]](
                h, jnp.full((B,), t, jnp.int32), gate, jnp.ones((B,)),
                jnp.full((B,), slots[l], jnp.int32), ck, cv, cp, cval,
                lp["attn_norm"], lp["wq"], lp["wk"], lp["wv"], lp["wo"],
                lp["mlp_norm"], lp["w1"], lp["w2"])
            caches[l] = [ck, cv, cp, cval]
            slots[l] += 1
        (lg,) = logits_fn(h, params["final_norm"], params["embed"])
        out.append(lg)
    return jnp.stack(out, axis=1), slots, drops


def test_decode_matches_masked_forward():
    """THE serving invariant: token-by-token decode through per-block step
    functions == the L2 masked forward under causal router routing."""
    cfg, params = mk(routing="mod_interleaved", capacity_frac=0.25)
    cfg = ModelConfig(**{**MICRO, "seq_len": 16},
                      routing="mod_interleaved", capacity_frac=0.25)
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    t = jax.random.randint(jax.random.PRNGKey(1), (1, 16), 0, cfg.vocab_size)
    want, _ = model.forward(params, t, cfg, routing_mode="router")
    got, slots, drops = decode_sequence(cfg, params, t)
    assert drops == 0
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4)


def test_decode_vanilla_matches_forward():
    cfg = ModelConfig(**{**MICRO, "seq_len": 12}, routing="none")
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    t = jax.random.randint(jax.random.PRNGKey(1), (1, 12), 0, cfg.vocab_size)
    want, _ = model.forward(params, t, cfg)
    got, slots, _ = decode_sequence(cfg, params, t)
    assert all(s == 12 for s in slots.values())
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4)


def test_decode_capacity_drop():
    """When a routed block's cache fills, later tokens are dropped from the
    block (routed around), and the stream stays finite/causal."""
    cfg = ModelConfig(**{**MICRO, "seq_len": 16},
                      routing="mod_every", capacity_frac=0.25)
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    t = jax.random.randint(jax.random.PRNGKey(1), (1, 16), 0, cfg.vocab_size)
    got, slots, drops = decode_sequence(cfg, params, t, cache_len=3)
    assert all(s <= 3 for s in slots.values())
    assert np.all(np.isfinite(np.asarray(got)))


def test_cache_lengths_slack_and_bounds():
    cfg = ModelConfig(**MICRO, routing="mod_interleaved", capacity_frac=0.125)
    cls = sampling.cache_lengths(cfg, 256, slack=1.5)
    assert cls[0] == 256 and cls[2] == 256      # full blocks
    assert cls[1] == cls[3] == 48               # ceil(0.125*256*1.5)
    # slack never exceeds the sequence itself
    cls2 = sampling.cache_lengths(cfg, 8, slack=100.0)
    assert cls2[1] == 8


# ---------------------------------------------------------------------------
# checkpoint format round-trip (shared ABI with rust)
# ---------------------------------------------------------------------------

def test_ckpt_roundtrip(tmp_path):
    tensors = {
        "a": np.arange(12, dtype=np.float32).reshape(3, 4),
        "b.scalar": np.asarray(3.5, np.float32),
        "c_int": np.arange(5, dtype=np.int32),
    }
    path = str(tmp_path / "t.ckpt")
    ckpt.save(path, tensors)
    back = ckpt.load(path)
    assert set(back) == set(tensors)
    for k in tensors:
        np.testing.assert_array_equal(back[k], tensors[k])
        assert back[k].dtype == tensors[k].dtype


def test_ckpt_rejects_bad_magic(tmp_path):
    p = tmp_path / "bad.ckpt"
    p.write_bytes(b"NOTMAGIC" + b"\x00" * 16)
    with pytest.raises(ValueError):
        ckpt.load(str(p))
