"""L1 correctness: every Pallas kernel vs its pure-jnp oracle.

Hypothesis sweeps shapes and dtypes; fixed-seed examples pin the edge cases
(single token, capacity 1, full capacity, non-divisible block sizes).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import kernels
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")

ATOL = 2e-5


def rand(key, shape, dtype=jnp.float32):
    return jax.random.normal(jax.random.PRNGKey(key), shape, dtype)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

@settings(max_examples=12, deadline=None)
@given(
    b=st.integers(1, 3), h=st.integers(1, 3),
    s=st.sampled_from([1, 3, 8, 17, 64]),
    dh=st.sampled_from([4, 16, 32]),
)
def test_attention_matches_ref(b, h, s, dh):
    q, k, v = (rand(i, (b, h, s, dh)) for i in range(3))
    got = kernels.causal_attention(q, k, v, block_q=16, block_k=16)
    want = ref.causal_attention_ref(q, k, v)
    np.testing.assert_allclose(got, want, atol=ATOL)


def test_attention_respects_causality():
    """Perturbing a future token must not change earlier outputs."""
    b, h, s, dh = 1, 2, 12, 8
    q, k, v = (rand(i, (b, h, s, dh)) for i in range(3))
    base = kernels.causal_attention(q, k, v)
    k2 = k.at[:, :, -1].add(100.0)
    v2 = v.at[:, :, -1].add(100.0)
    pert = kernels.causal_attention(q, k2, v2)
    np.testing.assert_allclose(base[:, :, :-1], pert[:, :, :-1], atol=ATOL)
    assert not np.allclose(base[:, :, -1], pert[:, :, -1], atol=1e-3)


def test_attention_valid_mask_excludes_keys():
    """Keys with valid=0 behave as if absent."""
    b, h, s, dh = 2, 2, 16, 8
    q, k, v = (rand(i, (b, h, s, dh)) for i in range(3))
    valid = jnp.asarray(np.random.RandomState(0).rand(b, s) > 0.3)
    valid = valid.at[:, 0].set(True)  # every query has >= 1 valid key
    got = kernels.causal_attention(q, k, v, valid_k=valid)
    want = ref.causal_attention_ref(q, k, v, valid_k=valid)
    np.testing.assert_allclose(got, want, atol=ATOL)


def test_attention_gathered_positions():
    """MoD compact path: non-contiguous original positions drive the mask."""
    b, h, c, dh = 2, 2, 6, 8
    q, k, v = (rand(i, (b, h, c, dh)) for i in range(3))
    pos = jnp.asarray([[0, 3, 4, 7, 10, 15], [1, 2, 5, 6, 11, 12]], jnp.int32)
    got = kernels.causal_attention(q, k, v, pos, pos)
    want = ref.causal_attention_ref(q, k, v, pos_q=pos, pos_k=pos)
    np.testing.assert_allclose(got, want, atol=ATOL)


def test_attention_single_token():
    q, k, v = (rand(i, (1, 1, 1, 4)) for i in range(3))
    got = kernels.causal_attention(q, k, v)
    np.testing.assert_allclose(got, v, atol=ATOL)  # softmax over self only


# ---------------------------------------------------------------------------
# mlp
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(
    rows=st.sampled_from([1, 5, 16, 33, 128]),
    d=st.sampled_from([8, 32]),
    f=st.sampled_from([16, 64]),
)
def test_mlp_matches_ref(rows, d, f):
    x = rand(0, (rows, d))
    w1 = rand(1, (d, f)) * 0.2
    w2 = rand(2, (f, d)) * 0.2
    got = kernels.fused_mlp(x, w1, w2, block_m=16)
    np.testing.assert_allclose(got, ref.mlp_ref(x, w1, w2), atol=ATOL)


def test_mlp_batched_shape():
    x = rand(0, (2, 7, 16))
    w1, w2 = rand(1, (16, 32)) * 0.2, rand(2, (32, 16)) * 0.2
    got = kernels.fused_mlp(x, w1, w2, block_m=4)
    assert got.shape == (2, 7, 16)
    np.testing.assert_allclose(got, ref.mlp_ref(x, w1, w2), atol=ATOL)


# ---------------------------------------------------------------------------
# router scores
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(b=st.integers(1, 3), s=st.sampled_from([1, 7, 32, 100]),
       d=st.sampled_from([8, 64]))
def test_router_scores_match_ref(b, s, d):
    x = rand(0, (b, s, d))
    w = rand(1, (d,))
    got = kernels.router_scores(x, w, block_s=16)
    np.testing.assert_allclose(got, ref.router_scores_ref(x, w), atol=ATOL)


# ---------------------------------------------------------------------------
# gather / scatter (the MoD data movement)
# ---------------------------------------------------------------------------

@settings(max_examples=12, deadline=None)
@given(b=st.integers(1, 3), s=st.sampled_from([4, 16, 50]),
       frac=st.sampled_from([0.1, 0.25, 0.5, 1.0]), d=st.sampled_from([4, 32]))
def test_gather_scatter_roundtrip(b, s, frac, d):
    c = max(1, int(round(frac * s)))
    x = rand(0, (b, s, d))
    scores = rand(1, (b, s))
    idx, mask = ref.topk_mask_ref(scores, c)
    got = kernels.gather_tokens(x, idx)
    want = ref.gather_tokens_ref(x, idx)
    np.testing.assert_allclose(got, want, atol=0)

    upd = rand(2, (b, c, d))
    gates = rand(3, (b, c))
    got2 = kernels.scatter_add_weighted(x, upd, idx, gates)
    want2 = ref.scatter_add_weighted_ref(x, upd, idx, gates)
    np.testing.assert_allclose(got2, want2, atol=ATOL)


def test_scatter_leaves_unselected_untouched():
    b, s, c, d = 2, 10, 3, 4
    x = rand(0, (b, s, d))
    idx = jnp.asarray([[1, 4, 7], [0, 5, 9]], jnp.int32)
    upd = jnp.ones((b, c, d))
    gates = jnp.ones((b, c))
    out = kernels.scatter_add_weighted(x, upd, idx, gates)
    sel = np.zeros((b, s), bool)
    for bi in range(b):
        sel[bi, np.asarray(idx)[bi]] = True
    np.testing.assert_allclose(np.asarray(out)[~sel], np.asarray(x)[~sel])
    np.testing.assert_allclose(np.asarray(out)[sel], np.asarray(x)[sel] + 1.0,
                               atol=ATOL)


# ---------------------------------------------------------------------------
# top-k selection invariants
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(b=st.integers(1, 4), s=st.integers(2, 40), data=st.data())
def test_topk_invariants(b, s, data):
    k = data.draw(st.integers(1, s))
    scores = rand(0, (b, s))
    idx, mask = ref.topk_mask_ref(scores, k)
    idx_np, mask_np, sc = np.asarray(idx), np.asarray(mask), np.asarray(scores)
    # exactly k selected, indices strictly ascending (unique + ordered)
    assert mask_np.sum(axis=1).tolist() == [k] * b
    assert np.all(np.diff(idx_np, axis=1) > 0)
    # selected scores dominate unselected scores per row
    for bi in range(b):
        sel = sc[bi][mask_np[bi]]
        unsel = sc[bi][~mask_np[bi]]
        if unsel.size:
            assert sel.min() >= unsel.max() - 1e-6


def test_topk_selects_largest():
    scores = jnp.asarray([[0.1, 5.0, -2.0, 3.0]])
    idx, mask = ref.topk_mask_ref(scores, 2)
    assert idx.tolist() == [[1, 3]]
    assert mask.tolist() == [[False, True, False, True]]


# ---------------------------------------------------------------------------
# composed MoD block (gather -> f -> gated scatter)
# ---------------------------------------------------------------------------

def test_mod_block_ref_composition():
    """mod_block_ref == manual composition with a linear f."""
    b, s, c, d = 2, 12, 4, 8
    x = rand(0, (b, s, d))
    scores = rand(1, (b, s))
    idx, mask = ref.topk_mask_ref(scores, c)
    gates = jnp.take_along_axis(scores, idx, axis=1)
    w = rand(2, (d, d)) * 0.3

    out = ref.mod_block_ref(x, idx, gates, lambda xc, pos: xc @ w)
    xc = ref.gather_tokens_ref(x, idx)
    want = ref.scatter_add_weighted_ref(x, xc @ w, idx, gates)
    np.testing.assert_allclose(out, want, atol=ATOL)
    # bypassed tokens unchanged
    sel = np.asarray(mask)
    np.testing.assert_allclose(np.asarray(out)[~sel], np.asarray(x)[~sel])
