"""L2 model correctness: variants, routing semantics, paper Eq. (1) wiring."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.configs import ModelConfig, TrainConfig
from compile import layers, model, routing, train

jax.config.update("jax_platform_name", "cpu")

MICRO = dict(vocab_size=37, d_model=32, n_layers=4, n_heads=2, d_head=16,
             d_ff=64, seq_len=32)


def mk(key=0, **kw):
    cfg = ModelConfig(**MICRO, **kw)
    params = model.init_params(cfg, jax.random.PRNGKey(key))
    return cfg, params


def toks(cfg, b=2, key=1):
    return jax.random.randint(jax.random.PRNGKey(key), (b, cfg.seq_len), 0,
                              cfg.vocab_size)


ALL_VARIANTS = [
    dict(routing="none"),
    dict(routing="mod_interleaved", capacity_frac=0.25),
    dict(routing="mod_every", capacity_frac=0.25),
    dict(routing="stochastic", capacity_frac=0.25, train_predictor=False),
    dict(ff_mode="moe", n_experts=2),
    dict(routing="mod_interleaved", capacity_frac=0.25, ff_mode="moe",
         n_experts=2),
    dict(ff_mode="mode_integrated", n_experts=2),
]


@pytest.mark.parametrize("kw", ALL_VARIANTS, ids=lambda kw: "-".join(
    f"{k}={v}" for k, v in kw.items()))
def test_forward_finite_all_variants(kw):
    cfg, params = mk(**kw)
    logits, aux = model.forward(params, toks(cfg), cfg,
                                rng=jax.random.PRNGKey(3))
    assert logits.shape == (2, cfg.seq_len, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits)))


def test_param_count_matches_config():
    for kw in ALL_VARIANTS:
        cfg, params = mk(**kw)
        n = sum(int(np.prod(p.shape)) for p in params.values())
        assert n == cfg.n_params(), kw


def test_param_flatten_roundtrip():
    cfg, params = mk(routing="mod_interleaved")
    flat = model.flatten_params(cfg, params)
    back = model.unflatten_params(cfg, flat)
    assert set(back) == set(params)
    for k in params:
        np.testing.assert_array_equal(back[k], params[k])


def test_mod_bypassed_tokens_keep_residual():
    """A token routed around every MoD block with zero full blocks is
    untouched: capacity-0-like behaviour via mod_every on a 1-layer net."""
    cfg = ModelConfig(**{**MICRO, "n_layers": 1},
                      routing="mod_every", capacity_frac=0.25)
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    t = toks(cfg, b=1)
    x_in = layers.embed(t, params)
    logits, aux = model.forward(params, t, cfg)
    mask = np.asarray(aux["topk_masks"][0][0])
    # bypassed positions: unembed(embed(x)) exactly
    want = layers.unembed(x_in, params)
    got, ref_ = np.asarray(logits[0]), np.asarray(want[0])
    np.testing.assert_allclose(got[~mask], ref_[~mask], atol=1e-5)
    assert not np.allclose(got[mask], ref_[mask], atol=1e-3)


def test_capacity_full_equals_vanilla():
    """capacity_frac=1.0 MoD with gate forced to 1 reduces to vanilla.

    We verify structurally: the compact path with C=S selects every token,
    so the only difference from vanilla is the gate multiply. With router
    weights zeroed the gate is 0 => output == pure residual stream.
    """
    cfg, params = mk(routing="mod_every", capacity_frac=1.0)
    for l in range(cfg.n_layers):
        params[f"layer_{l:02d}.router_w"] = jnp.zeros_like(
            params[f"layer_{l:02d}.router_w"])
    t = toks(cfg, b=1)
    logits, aux = model.forward(params, t, cfg)
    x_in = layers.embed(t, params)
    want = layers.unembed(x_in, params)  # gate 0 -> nothing added
    np.testing.assert_allclose(np.asarray(logits), np.asarray(want),
                               atol=1e-4)


def test_routed_block_capacity_exact():
    cfg, params = mk(routing="mod_every", capacity_frac=0.25)
    logits, aux = model.forward(params, toks(cfg), cfg)
    c = cfg.capacity()
    for l, mask in aux["topk_masks"].items():
        assert np.asarray(mask).sum(axis=1).tolist() == [c, c]


def test_interleaved_routes_odd_blocks_only():
    cfg, _ = mk(routing="mod_interleaved")
    assert cfg.routed_layers() == [1, 3]
    cfg2, _ = mk(routing="mod_every")
    assert cfg2.routed_layers() == [0, 1, 2, 3]


def test_router_and_predictor_modes_run_causally():
    """Causal modes: future-token perturbation cannot change past logits."""
    cfg, params = mk(routing="mod_interleaved", capacity_frac=0.25)
    t = toks(cfg, b=1)
    t2 = t.at[0, -1].set((t[0, -1] + 1) % cfg.vocab_size)
    for mode in ("router", "predictor"):
        a, _ = model.forward(params, t, cfg, routing_mode=mode)
        b_, _ = model.forward(params, t2, cfg, routing_mode=mode)
        np.testing.assert_allclose(np.asarray(a[0, :-1]),
                                   np.asarray(b_[0, :-1]), atol=1e-5)


def test_topk_mode_is_noncausal():
    """The training-time top-k IS non-causal (the paper's sampling problem):
    a future token can evict a past token from the top-k."""
    cfg, params = mk(routing="mod_every", capacity_frac=0.125)
    # train a moment so router weights are non-trivial? not needed: random
    # router weights already make selection content-dependent.
    t = toks(cfg, b=1)
    t2 = t.at[0, -1].set((t[0, -1] + 7) % cfg.vocab_size)
    a, _ = model.forward(params, t, cfg, routing_mode="topk")
    b_, _ = model.forward(params, t2, cfg, routing_mode="topk")
    # at least some earlier-position logit moved
    assert not np.allclose(np.asarray(a[0, :-1]), np.asarray(b_[0, :-1]),
                           atol=1e-6)


def test_aux_bce_centers_sigmoid():
    """Gradient of the aux BCE pushes selected scores up, unselected down."""
    scores = jnp.asarray([[1.0, -1.0, 0.5, -0.5]])
    _, mask = routing.select_topk(scores, 2)  # selects 1.0 and 0.5

    g = jax.grad(lambda s: routing.router_aux_bce(s, mask))(scores)
    g = np.asarray(g)[0]
    m = np.asarray(mask)[0]
    assert np.all(g[m] < 0)   # descent raises selected scores
    assert np.all(g[~m] > 0)  # descent lowers unselected scores


def test_predictor_stop_gradient():
    """Predictor loss must not leak gradients into the trunk (paper 3.5)."""
    cfg, params = mk(routing="mod_interleaved", capacity_frac=0.25)
    t = toks(cfg, b=1)

    def pred_only_loss(p):
        logits, aux = model.forward(p, t, cfg)
        loss = jnp.zeros(())
        for l, pl in aux["pred_logits"].items():
            bce, _ = routing.predictor_bce(pl, aux["topk_masks"][l])
            loss = loss + bce
        return loss

    g = jax.grad(pred_only_loss)(params)
    # trunk weights get zero gradient; predictor weights get nonzero
    assert float(jnp.abs(g["layer_01.wq"]).max()) == 0.0
    assert float(jnp.abs(g["embed"]).max()) == 0.0
    assert float(jnp.abs(g["layer_01.pred.w1"]).max()) > 0.0


def test_stochastic_routing_varies_with_seed():
    cfg, params = mk(routing="stochastic", capacity_frac=0.25,
                     train_predictor=False)
    t = toks(cfg)
    a, _ = model.forward(params, t, cfg, rng=jax.random.PRNGKey(0))
    b_, _ = model.forward(params, t, cfg, rng=jax.random.PRNGKey(1))
    assert not np.allclose(np.asarray(a), np.asarray(b_))


def test_moe_expert_capacity():
    cfg, params = mk(ff_mode="moe", n_experts=2)
    x = jax.random.normal(jax.random.PRNGKey(2), (2, cfg.seq_len, cfg.d_model))
    lp = model.layer_view(params, 0)
    out, noop = routing.moe_mlp(x, lp, cfg, integrated=False)
    assert out.shape == x.shape
    assert noop is None
    assert np.all(np.isfinite(np.asarray(out)))


def test_integrated_mode_has_noop_mask():
    cfg, params = mk(ff_mode="mode_integrated", n_experts=2)
    logits, aux = model.forward(params, toks(cfg), cfg)
    assert len(aux["noop_masks"]) == cfg.n_layers
    for m in aux["noop_masks"].values():
        assert m.dtype == bool


def test_rope_relative_shift():
    """RoPE: attention logits depend only on relative positions."""
    b, h, s, dh = 1, 1, 4, 8
    q = jax.random.normal(jax.random.PRNGKey(0), (b, h, s, dh))
    k = jax.random.normal(jax.random.PRNGKey(1), (b, h, s, dh))
    p0 = jnp.arange(s, dtype=jnp.int32)[None]
    p5 = p0 + 5
    q0 = layers.apply_rope(q, p0, 10000.0)
    k0 = layers.apply_rope(k, p0, 10000.0)
    q5 = layers.apply_rope(q, p5, 10000.0)
    k5 = layers.apply_rope(k, p5, 10000.0)
    a0 = jnp.einsum("bhqd,bhkd->bhqk", q0, k0)
    a5 = jnp.einsum("bhqd,bhkd->bhqk", q5, k5)
    np.testing.assert_allclose(np.asarray(a0), np.asarray(a5), atol=1e-4)


def test_cross_entropy_uniform_baseline():
    cfg, params = mk()
    v = cfg.vocab_size
    logits = jnp.zeros((2, 8, v))
    t = jnp.zeros((2, 8), jnp.int32)
    ce = train.cross_entropy(logits, t)
    np.testing.assert_allclose(float(ce), np.log(v), rtol=1e-5)
